"""Executed in a subprocess with 8 forced host devices (see
test_mesh_hwa.py).

Verifies the tentpole properties of mesh-native HWA on a (2,2,2)
(replica, data, model) mesh:

  1. mesh-native train step == vmap-path train step == single-device
     oracle, within 1e-5 after several steps (f32 smoke model);
  2. mesh-native sync == stacked-mean oracle; replicas restart equal;
     the slide window advances;
  3. the lowered inner train step contains NO collective crossing the
     replica mesh axis — inter-replica traffic happens only in hwa_sync
     (every H steps), which is the paper's communication amortization;
  4. the mesh-RESIDENT sync (shard-aware packed layout, fully-manual
     shard_map) is bit-identical to the single-device fused Pallas path
     AND to the per-leaf reference, compiles to exactly ONE Pallas launch
     per sync, and its HLO contains exactly one replica-axis all-reduce
     and ZERO collectives crossing any other axis (collective-free
     packed-W̄ assembly);
  5. the TWO-LEVEL sync tree (pod-carved (pod=2, replica=2, model=2)
     mesh, K=4) is bit-identical — 0 ULP — to the flat path and to the
     per-leaf grouped reference; its lowered HLO passes the per-level
     sync_collective_audit (inner sync: one per-pod all-reduce, zero
     cross-pod; outer sync: exactly one cross-pod all-reduce on top);
     the tuple-axis train step is collective-free over pod AND replica;
     and the legacy GSPMD fallback is a hard error on this CPU mesh
     unless REPRO_ALLOW_LEGACY_ASSEMBLY=1;
  6. FSDP mixed tilings (fsdp=True rules: data-only, model-only AND
     data×model leaves at once) sync through the GROUPED mesh-resident
     layout — per-group window-buffer tuples, ≤ n_groups Pallas
     launches, still exactly one replica all-reduce and zero assembly
     collectives — bit-identical to the per-leaf reference, with no
     legacy-assembly error;
  7. COMPRESSED WA precision (PR 10): the bf16-ring flat kernel sync and
     the fp8-ring + fp8-comms tree sync stay within the per-dtype
     relative-ULP budgets of benchmarks/thresholds.json (the same
     numbers bench-check guards) against the exact-f32 legs above; the
     fp8 tree's cross-pod hop compiles to the u8-payload + f32-scales
     all-gather pair (the integer bit-view XLA cannot widen).

All oracles are computed on HOST-materialized copies: eagerly packing
DISTRIBUTED leaves (a concat across differently-sharded operands) is
miscompiled by XLA 0.4.37's CPU SPMD partitioner — replicated shards get
overcounted ~(data×model)-fold. The legacy GSPMD sync path hit the same
partitioner pattern in-jit, which is why the mesh-resident layout now
assembles shard-locally and leaves nothing for the partitioner to get
wrong (the legacy fallback is still asserted, structurally only, below).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import use_mesh
from repro.common.packing import pack_spec, pack_stacked, unpack
from repro.configs import get_smoke_config
from repro.core.hwa import HWAConfig
from repro.core.offline import window_init, window_update
from repro.launch.hlo import (collectives_crossing_axis, count_pallas_calls,
                              sync_collective_audit)
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import SyncPlan, build_hwa_bundles
from repro.models.registry import build_model
from repro.models.types import InputShape
from repro.optim import apply_updates, sgd
from repro.sharding.rules import make_tp_rules

ok = True
K, B, S, N_STEPS, LR = 2, 8, 16, 3, 0.1


def check(name, cond):
    global ok
    print(("PASS " if cond else "FAIL ") + name)
    ok = ok and cond


def to_host(tree):
    """Host copies — oracle math must never run on distributed arrays
    (see module docstring)."""
    return jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), tree)


def tree_err(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


mesh = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
rules = make_tp_rules(mesh, replica_axis="replica")
cfg = get_smoke_config("granite-3-2b")
lm = build_model(cfg)
hwa_cfg = HWAConfig(n_replicas=K, window=3)
shape = InputShape("tiny", seq_len=S, global_batch=B, kind="train")
specs, dims = input_specs(cfg, shape)

params = lm.init(jax.random.key(0))
stack2 = lambda t: jax.tree.map(lambda x: jnp.stack([x, x]), t)
opt = sgd(momentum=0.9, weight_decay=5e-4)


def batches(step):
    ks = jax.random.split(jax.random.key(100 + step), 2)
    return {"tokens": jax.random.randint(ks[0], (K, B, S), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(ks[1], (K, B, S), 0,
                                          cfg.vocab_size)}


# every bundle in this file comes from the ONE declarative constructor
# (PR 10); the old make_*hwa*_step names are deprecated wrappers
def mk_train(lm_, rules_, hwa, **kw):
    plan = SyncPlan(hwa=hwa, optimizer="sgd", lr=LR, **kw)
    return build_hwa_bundles(lm_, rules_, plan, specs, dims).train


def mk_sync(lm_, rules_, hwa, **kw):
    return build_hwa_bundles(lm_, rules_, SyncPlan(hwa=hwa, **kw)).sync


# ---- leg A: mesh-native shard_map path ------------------------------------
mesh_train = mk_train(lm, rules, hwa_cfg)
mesh_train_c = mesh_train.lower(mesh).compile()
a_inner, a_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
with use_mesh(mesh):
    for step in range(N_STEPS):
        a_inner, a_opt, a_losses = mesh_train_c(a_inner, a_opt,
                                                batches(step))
check("mesh-native: finite per-replica losses",
      bool(jnp.all(jnp.isfinite(a_losses))))

# ---- leg B: vmap path compiled on the same mesh ---------------------------
vmap_train = mk_train(lm, rules, hwa_cfg, mesh_native=False)
vmap_train_c = vmap_train.lower(mesh).compile()
b_inner, b_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
with use_mesh(mesh):
    for step in range(N_STEPS):
        b_inner, b_opt, _ = vmap_train_c(b_inner, b_opt, batches(step))

# ---- leg C: single-device vmap oracle -------------------------------------
def one(p, o, b):
    (l, m), g = jax.value_and_grad(
        lambda q: lm.loss(q, b), has_aux=True)(p)
    upd, o2 = opt.update(g, o, p, LR)
    return apply_updates(p, upd), o2, l


c_inner, c_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
for step in range(N_STEPS):
    c_inner, c_opt, _ = jax.vmap(one)(c_inner, c_opt, batches(step))

err_ab = tree_err(a_inner, b_inner)
err_ac = tree_err(a_inner, c_inner)
check(f"mesh-native == vmap path after {N_STEPS} steps "
      f"(err={err_ab:.2e})", err_ab < 1e-5)
check(f"mesh-native == single-device oracle (err={err_ac:.2e})",
      err_ac < 1e-5)

# ---- sync: mesh-native vs stacked oracle ----------------------------------
# oracles first (the sync bundle donates its inputs), on HOST copies
a_host = to_host(a_inner)
a_host2 = a_host                      # same diverged state for the kernel leg
a_inner2 = jax.tree.map(jnp.array, a_host)   # fresh copies: sync donates
outer_oracle = jax.tree.map(lambda x: jnp.mean(x, 0), a_host)
ws_oracle, wa_oracle = window_update(
    window_init(params, hwa_cfg.window), outer_oracle)

sync = mk_sync(lm, rules, hwa_cfg)
sync_c = sync.lower(mesh).compile()
spec = sync.pack_spec               # window state is packed (I, P)/(P,)
check(f"sync: pack_spec is shard-aware (axes={spec.axes}, "
      f"shards={spec.shards})", spec.shards > 1 and len(spec.axes) >= 1)
ring = jnp.zeros((hwa_cfg.window, spec.padded), jnp.float32)
total = jnp.zeros((spec.padded,), jnp.float32)
zero = jnp.zeros((), jnp.int32)
with use_mesh(mesh):
    (s_inner, s_ring, s_total, s_count, s_nidx, s_wa,
     s_cycle) = sync_c(a_inner, ring, total, zero, zero, zero)
check("sync: replicas equal after restart",
      tree_err(jax.tree.map(lambda x: x[0], s_inner),
               jax.tree.map(lambda x: x[1], s_inner)) == 0.0)
err_outer = tree_err(jax.tree.map(lambda x: x[0], s_inner), outer_oracle)
check(f"sync: restart == stacked mean (err={err_outer:.2e})",
      err_outer < 1e-5)
err_wa = tree_err(s_wa, wa_oracle)
check(f"sync: window average == oracle (err={err_wa:.2e})", err_wa < 1e-5)
check("sync: count/cycle advanced",
      int(s_count) == 1 and int(s_cycle) == 1)

# ---- mesh-RESIDENT kernel sync: the Pallas path runs on the mesh ----------
# Bit-parity vs (a) the single-device fused kernel and (b) the per-leaf
# reference — the packed layouts differ (shard-aware vs contiguous), so
# all comparisons go through unpacked leaf views of host copies.
hwa_cfg_k = HWAConfig(n_replicas=K, window=3, use_kernels=True)
sync_k = mk_sync(lm, rules, hwa_cfg_k)
sync_kc = sync_k.lower(mesh).compile()
spec_k = sync_k.pack_spec
ring_k = jnp.zeros((hwa_cfg_k.window, spec_k.padded), jnp.float32)
total_k = jnp.zeros((spec_k.padded,), jnp.float32)
with use_mesh(mesh):
    out_k = sync_kc(a_inner2, ring_k, total_k, zero, zero, zero)
(k_inner, k_ring, k_total, k_count, k_nidx, k_wa, k_cycle) = out_k
k_ring_h, k_total_h = to_host(k_ring), to_host(k_total)

# (a) single-device fused path (one hwa_sync_packed launch, default spec)
from repro.kernels import ops as kops
spec1 = pack_spec(params)
stacked1 = pack_stacked(a_host2, spec1)
ring1, total1, avg1 = kops.hwa_sync_packed(
    stacked1, jnp.zeros((hwa_cfg_k.window, spec1.padded), jnp.float32),
    jnp.zeros((spec1.padded,), jnp.float32), zero, jnp.zeros(()),
    jnp.ones(()))
check("mesh-resident kernel sync: W̿ bit-equal to single-device fused",
      tree_equal(k_wa, unpack(avg1, spec1)))
check("mesh-resident kernel sync: restart bit-equal to fused ring slot",
      tree_equal(jax.tree.map(lambda x: x[0], k_inner),
                 unpack(ring1[0], spec1)))
check("mesh-resident kernel sync: ring slot bit-equal",
      tree_equal(unpack(k_ring_h[0], spec_k), unpack(ring1[0], spec1)))
check("mesh-resident kernel sync: total bit-equal",
      tree_equal(unpack(k_total_h, spec_k), unpack(total1, spec1)))

# (b) per-leaf reference (kernel-matching math: mean = sum × 1/K)
from repro.kernels import ref as kref
ring_tree = jax.tree.map(
    lambda x: jnp.zeros((hwa_cfg_k.window,) + x.shape), params)
total_tree = jax.tree.map(jnp.zeros_like, params)
triples = jax.tree.map(
    lambda s, r, t: kref.wa_sync_fused_ref(s, r, t, 0, 0.0, 1.0),
    a_host2, ring_tree, total_tree)
is3 = lambda x: isinstance(x, tuple) and len(x) == 3
leaf_wa = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
check("mesh-resident kernel sync: W̿ bit-equal to per-leaf reference",
      tree_equal(k_wa, leaf_wa))
check("mesh-resident kernel sync: window advanced",
      int(k_count) == 1 and int(k_cycle) == 1)

# exactly ONE Pallas launch per sync, counted structurally in the jaxpr
jaxpr_k = jax.make_jaxpr(sync_k.fn)(*sync_k.abstract_args)
check(f"mesh-resident kernel sync: one pallas_call in the jaxpr "
      f"(found {count_pallas_calls(jaxpr_k)})",
      count_pallas_calls(jaxpr_k) == 1)

# ---- HLO structure: replica-axis traffic only in hwa_sync -----------------
train_hlo = mesh_train_c.as_text()
cross_train = collectives_crossing_axis(train_hlo, mesh, "replica")
check(f"train step: zero replica-crossing collectives "
      f"(found {len(cross_train)})", len(cross_train) == 0)

for label, compiled in [("sync", sync_c), ("kernel sync", sync_kc)]:
    audit = sync_collective_audit(compiled.as_text(), mesh)
    check(f"{label} step: exactly one replica-crossing collective, the "
          f"weight all-reduce (found {[op for op, _ in audit['replica']]})",
          audit["replica_allreduce_only"])
    n_other = {ax: len(h) for ax, h in audit["other"].items()}
    check(f"{label} step: packed-W̄ assembly is collective-free "
          f"(non-replica crossings: {n_other})", audit["assembly_free"])

# the legacy (non-mesh-resident) fallback is a HARD ERROR on multi-device
# CPU meshes (XLA 0.4.37 miscompiles its packed-W̄ assembly — see
# launch/sync/legacy.py); REPRO_ALLOW_LEGACY_ASSEMBLY=1 is the escape
# hatch for HLO-introspection-only callers, under which it still compiles
# and structurally pays the assembly redistribution the aligned layout
# removes
_prior_hatch = os.environ.pop("REPRO_ALLOW_LEGACY_ASSEMBLY", None)
try:
    legacy_raised = False
    try:
        mk_sync(lm, rules, hwa_cfg, mesh_resident=False)
    except RuntimeError:
        legacy_raised = True
    check("legacy fallback: hard error on the multi-device CPU mesh",
          legacy_raised)
    os.environ["REPRO_ALLOW_LEGACY_ASSEMBLY"] = "1"
    sync_legacy = mk_sync(lm, rules, hwa_cfg, mesh_resident=False)
    legacy_audit = sync_collective_audit(
        sync_legacy.lower(mesh).compile().as_text(), mesh)
    n_legacy = sum(len(h) for h in legacy_audit["other"].values())
    check(f"legacy fallback (escape hatch): compiles, assembly pays "
          f"non-replica collectives (found {n_legacy})", n_legacy >= 1)
finally:
    if _prior_hatch is None:
        os.environ.pop("REPRO_ALLOW_LEGACY_ASSEMBLY", None)
    else:
        os.environ["REPRO_ALLOW_LEGACY_ASSEMBLY"] = _prior_hatch

# ---- FSDP mixed tilings: the GROUPED mesh-resident packed sync ------------
# fsdp=True rules shard "embed" dims over data too, so leaves tile over
# data-only, model-only, AND data×model jointly — no single packed
# super-axis covers them, and before the grouped layout this tree fell
# back to the legacy GSPMD assembly (the hard error asserted above). Now
# choose_resident_spec returns a grouped PackSpec (one PackGroup per
# placement key), the window state rides as per-group buffer tuples, the
# sync costs one kernel launch per group and STILL exactly one replica
# all-reduce with zero assembly collectives, and every result is
# bit-identical to the per-leaf reference (same math, different layout).
from repro.common.packing import merge_groups, window_buffers

rules_f = make_tp_rules(mesh, replica_axis="replica", fsdp=True)
sync_f = mk_sync(lm, rules_f, hwa_cfg_k)                   # builds: no
check("fsdp sync: grouped layout chosen, no legacy-assembly error "     # raise
      f"(n_groups={sync_f.pack_spec.n_groups})",
      sync_f.pack_spec.is_grouped and sync_f.pack_spec.n_groups >= 2)
spec_f = sync_f.pack_spec
sync_fc = sync_f.lower(mesh).compile()
ring_f, total_f = window_buffers(spec_f, hwa_cfg_k.window)
with use_mesh(mesh):
    (fs_inner, fs_ring, fs_total, fs_count, fs_nidx, fs_wa,
     fs_cycle) = sync_fc(jax.tree.map(jnp.array, a_host2), ring_f, total_f,
                         zero, zero, zero)
check("fsdp sync: W̿ bit-equal to per-leaf reference",
      tree_equal(fs_wa, leaf_wa))
check("fsdp sync: restart bit-equal to single-device fused ring slot",
      tree_equal(jax.tree.map(lambda x: x[0], fs_inner),
                 unpack(ring1[0], spec1)))
fs_ring_h = to_host(fs_ring)
check("fsdp sync: merged group ring slot bit-equal",
      tree_equal(unpack(merge_groups(tuple(r[0] for r in fs_ring_h),
                                     spec_f), spec_f),
                 unpack(ring1[0], spec1)))
check("fsdp sync: window advanced",
      int(fs_count) == 1 and int(fs_cycle) == 1)
audit_f = sync_collective_audit(sync_fc.as_text(), mesh,
                                n_groups=spec_f.n_groups)
check("fsdp sync: grouped audit ok (one replica all-reduce, zero "
      f"assembly crossings; replica="
      f"{[op for op, _ in audit_f['replica']]})",
      audit_f["grouped_sync_ok"])
n_launch_f = count_pallas_calls(
    jax.make_jaxpr(sync_f.fn)(*sync_f.abstract_args))
check(f"fsdp sync: pallas launches ≤ n_groups "
      f"({n_launch_f} ≤ {spec_f.n_groups})",
      1 <= n_launch_f <= spec_f.n_groups)

# ---- resilient (alive-masked) sync ----------------------------------------
# With hwa_cfg.resilient the K-mean becomes the alive-masked elastic
# mean (repro.resilience.health). Contract checked here: (a) with every
# replica healthy it is BITWISE identical to the plain packed sync —
# masking with an all-true mask adds exact zeros and the renormalized
# inverse pins the trace-time f32(1/K); (b) a NaN-poisoned replica is
# excluded and re-seeded from the finite W̄ of the survivors; (c) the
# lowered HLO carries exactly 2 replica-crossing all-reduces (k_alive +
# masked weights, unmergeable by construction) plus the budgeted
# non-replica health-stats psum — audited via the bundle's own contract.
from repro.analysis.collectives import check_collective_contract
from repro.resilience.faults import poison_replica

hwa_cfg_r = HWAConfig(n_replicas=K, window=3, resilient=True)
sync_r = mk_sync(lm, rules, hwa_cfg_r)
sync_rc = sync_r.lower(mesh).compile()
spec_r = sync_r.pack_spec
check("resilient sync: same packed layout as the plain sync",
      spec_r.padded == spec.padded)


def fresh_window_r():
    return (jnp.zeros((hwa_cfg_r.window, spec_r.padded), jnp.float32),
            jnp.zeros((spec_r.padded,), jnp.float32))


ring_r, total_r = fresh_window_r()
with use_mesh(mesh):
    (r_inner, r_ring, r_total, r_count, r_nidx, r_wa, r_cycle,
     r_alive) = sync_rc(jax.tree.map(jnp.array, a_host), ring_r, total_r,
                        zero, zero, zero)
check("resilient sync (all healthy): alive mask is all-true",
      bool(jnp.all(r_alive)) and r_alive.shape == (K,))
check("resilient sync (all healthy): restart BIT-equal to plain sync",
      tree_equal(to_host(r_inner), to_host(s_inner)))
check("resilient sync (all healthy): W̿ BIT-equal to plain sync",
      tree_equal(to_host(r_wa), to_host(s_wa)))
check("resilient sync (all healthy): ring/total BIT-equal to plain sync",
      tree_equal(to_host((r_ring, r_total)), to_host((s_ring, s_total))))
check("resilient sync (all healthy): counters match plain sync",
      int(r_count) == int(s_count) and int(r_cycle) == int(s_cycle))

# (b) poison replica 1: survivors' mean is replica 0 exactly (K=2), so
# every replica restarts bit-equal to replica 0's pre-sync weights
poisoned = jax.tree.map(jnp.array, poison_replica(a_host, 1))
ring_r, total_r = fresh_window_r()
with use_mesh(mesh):
    (p_inner, _, _, _, _, p_wa, _, p_alive) = sync_rc(
        poisoned, ring_r, total_r, zero, zero, zero)
check("resilient sync (poisoned): alive mask excludes replica 1",
      bool(p_alive[0]) and not bool(p_alive[1]))
check("resilient sync (poisoned): W̿ finite",
      all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(p_wa)))
rep0 = jax.tree.map(lambda x: x[0], a_host)
check("resilient sync (poisoned): restart bit-equal to the lone "
      "survivor's weights",
      tree_equal(to_host(jax.tree.map(lambda x: x[0], p_inner)), rep0)
      and tree_equal(to_host(jax.tree.map(lambda x: x[1], p_inner)), rep0))

# (c) collective structure: the bundle's declarative contract (2 replica
# all-reduces + 1 budgeted non-replica health psum, zero assembly)
r_contract = check_collective_contract(sync_rc.as_text(), mesh,
                                       sync_r.contract.collectives)
check(f"resilient sync: collective contract holds "
      f"(violations={r_contract['violations']})", r_contract["ok"])
n_rep_r = len(collectives_crossing_axis(sync_rc.as_text(), mesh,
                                        "replica"))
check(f"resilient sync: exactly 2 replica-crossing collectives "
      f"(found {n_rep_r})", n_rep_r == 2)

# vmap-path train step, for contrast, is *allowed* replica traffic (GSPMD
# may or may not insert it) — we only report it, the guarantee is the
# shard_map path's.
cross_vmap = collectives_crossing_axis(vmap_train_c.as_text(), mesh,
                                       "replica")
print(f"INFO vmap-path train step replica-crossing collectives: "
      f"{len(cross_vmap)}")

# ---- two-level sync tree: flat ↔ tree ↔ per-leaf bit-parity ---------------
# K = 4 replicas as 2 pods × 2 members on the pod-carved (2,2,2) mesh.
# The tree's outer sync computes the mean as the grouped psum composition
# (per-pod psum of 1/K-pre-scaled partials, then the cross-pod psum over
# CONTIGUOUS pods); with power-of-two counts every collective is a
# 2-member all-reduce (one commutative IEEE add) and every local sum uses
# the canonical halving order, so the composition is bit-identical —
# 0 ULP — to (a) the FLAT path (the vmap-path flat plan with two
# replicas resident per device on the plain mesh: local sum + one
# 2-member psum)
# and (b) the per-leaf host reference online_average_grouped
# (docs/ARCHITECTURE.md §4).
from repro.core.online import online_average_grouped, pod_mean_grouped
from repro.launch.mesh import make_tree_test_mesh
from repro.launch.steps import TwoLevel

K4 = 4
mesh_t = make_tree_test_mesh()          # (pod=2, replica=2, model=2)
rules_t = make_tp_rules(mesh_t, replica_axis=("pod", "replica"))
hwa4 = HWAConfig(n_replicas=K4, window=3, use_kernels=True, outer_every=2)
topo = TwoLevel("replica", "pod", outer_every=2)

# tuple-axis train step: collective-free over BOTH replica-population
# axes (the TwoLevel plan resolves replica_axis to ("pod", "replica"))
tree_bundles = build_hwa_bundles(lm, rules_t,
                                 SyncPlan(hwa=hwa4, topology=topo,
                                          optimizer="sgd", lr=LR),
                                 specs, dims)
tree_train = tree_bundles.train
tree_train_c = tree_train.lower(mesh_t).compile()


def batches4(step):
    ks = jax.random.split(jax.random.key(300 + step), 2)
    return {"tokens": jax.random.randint(ks[0], (K4, B, S), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(ks[1], (K4, B, S), 0,
                                          cfg.vocab_size)}


stack4 = lambda t: jax.tree.map(lambda x: jnp.stack([x] * K4), t)
t_inner0, t_opt0 = stack4(params), jax.vmap(opt.init)(stack4(params))
with use_mesh(mesh_t):
    t_inner0, t_opt0, t_losses = tree_train_c(t_inner0, t_opt0, batches4(0))
check("tree train step: finite per-replica losses",
      bool(jnp.all(jnp.isfinite(t_losses))))
tree_train_hlo = tree_train_c.as_text()
for ax in ("pod", "replica"):
    hits = collectives_crossing_axis(tree_train_hlo, mesh_t, ax)
    check(f"tree train step: zero {ax}-crossing collectives "
          f"(found {len(hits)})", len(hits) == 0)

# diverged 4-replica state (host-materialized; oracles below need it)
div4 = jax.tree.map(
    lambda x: x[None] + 0.1 * jax.random.normal(jax.random.key(11),
                                                (K4,) + x.shape), params)
div4_host = to_host(div4)
zero = jnp.zeros((), jnp.int32)


def run_sync(bundle, run_mesh, state, with_cycle):
    spec_ = bundle.pack_spec
    ring_ = jnp.zeros((hwa4.window, spec_.padded), jnp.float32)
    total_ = jnp.zeros((spec_.padded,), jnp.float32)
    c = bundle.lower(run_mesh).compile()
    extra = (zero,) if with_cycle else ()
    with use_mesh(run_mesh):
        return c(state, ring_, total_, zero, zero, *extra), c


# leg T: two-level OUTER sync (inner psum + cross-pod psum + window push)
outer_b = tree_bundles.sync
(t_out, outer_c) = run_sync(outer_b, mesh_t,
                            jax.tree.map(jnp.array, div4_host), True)
t_inner, _, _, t_count, _, t_wa, t_cycle = t_out
# leg F: FLAT path, K=4 with two replicas resident per device on the
# plain (replica=2, data=2, model=2) mesh (flat cfg: the flat builder
# refuses a silently-ignored outer_every; the sync math is identical)
import dataclasses
flat_b = mk_sync(lm, rules, dataclasses.replace(hwa4, outer_every=1),
                 mesh_native=False)
(f_out, _) = run_sync(flat_b, mesh,
                      jax.tree.map(jnp.array, div4_host), False)
f_inner, _, _, _, _, f_wa = f_out
# leg R: per-leaf host reference (canonical grouped mean; the first
# window push leaves W̿ == W̄ exactly, so it doubles as the W̿ oracle)
r_mean = online_average_grouped(div4_host, topo.pods(mesh_t))

check("two-level: all replicas restart equal",
      all(tree_equal(jax.tree.map(lambda x: x[0], t_inner),
                     jax.tree.map(lambda x, i=i: x[i], t_inner))
          for i in range(1, K4)))
check("two-level restart bit-equal to FLAT restart",
      tree_equal(jax.tree.map(lambda x: x[0], t_inner),
                 jax.tree.map(lambda x: x[0], f_inner)))
check("two-level W̿ bit-equal to FLAT W̿", tree_equal(t_wa, f_wa))
check("two-level restart bit-equal to per-leaf grouped reference",
      tree_equal(jax.tree.map(lambda x: x[0], t_inner), r_mean))
check("two-level W̿ bit-equal to per-leaf grouped reference",
      tree_equal(t_wa, r_mean))
check("two-level: window advanced on the outer sync",
      int(t_count) == 1 and int(t_cycle) == 1)

# the extended audit, per level: the outer sync is one inner-only + one
# outer-only all-reduce (no mixed groups, assembly-free) ...
audit_outer = sync_collective_audit(outer_c.as_text(), mesh_t,
                                    replica_axis="replica",
                                    outer_axis="pod")
check("two-level outer sync: audit outer_sync_ok "
      f"(inner={len(audit_outer['replica'])}, "
      f"outer={len(audit_outer['outer'])}, "
      f"mixed={len(audit_outer['mixed'])})", audit_outer["outer_sync_ok"])

# ... and the INNER sync crosses ONLY the inner (per-pod) groups
inner_b = tree_bundles.inner_sync
inner_c = inner_b.lower(mesh_t).compile()
with use_mesh(mesh_t):
    i_inner = inner_c(jax.tree.map(jnp.array, div4_host))
audit_inner = sync_collective_audit(inner_c.as_text(), mesh_t,
                                    replica_axis="replica",
                                    outer_axis="pod")
check("two-level inner sync: audit inner_sync_ok (zero cross-pod "
      f"collectives, found {len(audit_inner['outer'])})",
      audit_inner["inner_sync_ok"])
pm = pod_mean_grouped(div4_host, topo.pods(mesh_t))
pm_expanded = jax.tree.map(
    lambda m: jnp.concatenate([m[0:1], m[0:1], m[1:2], m[1:2]]), pm)
check("inner sync: restart bit-equal to per-pod means",
      tree_equal(i_inner, pm_expanded))
check("inner sync: pods stay diverged (no cross-pod averaging)",
      not tree_equal(jax.tree.map(lambda x: x[0], i_inner),
                     jax.tree.map(lambda x: x[2], i_inner)))

# k_local > 2 regression: with 4 replicas RESIDENT per device the kernel
# partial mean must yield to the canonical halving sum (the kernel's row
# reduction order is an XLA detail beyond 2 rows — packed.py gates it),
# keeping the flat kernel path bit-equal to the canonical/grouped means
from repro.core.online import online_average_canonical

K8 = 8
div8_host = to_host(jax.tree.map(
    lambda x: x[None] + 0.1 * jax.random.normal(jax.random.key(13),
                                                (K8,) + x.shape), params))
hwa8 = HWAConfig(n_replicas=K8, window=3, use_kernels=True)
flat8 = mk_sync(lm, rules, hwa8, mesh_native=False)  # k_local=4
spec8 = flat8.pack_spec
flat8_c = flat8.lower(mesh).compile()
with use_mesh(mesh):
    out8 = flat8_c(jax.tree.map(jnp.array, div8_host),
                   jnp.zeros((hwa8.window, spec8.padded), jnp.float32),
                   jnp.zeros((spec8.padded,), jnp.float32), zero, zero)
check("flat kernel sync, k_local=4: restart bit-equal to canonical "
      "halving mean",
      tree_equal(jax.tree.map(lambda x: x[0], out8[0]),
                 online_average_canonical(div8_host)))

# ---- flash-pallas train step: fully-manual kernel attention ---------------
# cfg.attn_impl == "flash_pallas" switches the mesh-native train step to a
# FULLY-manual shard_map (Pallas kernels are opaque to GSPMD — under the
# partial-auto map XLA would run them per-shard with global-shape
# semantics): attention fwd + the two recompute-bwd sweeps execute on
# true local shapes, data parallelism is an explicit grad pmean, and the
# bundle declares an EXACT LaunchBudget. Checks: finite losses, parity
# with a single-device flash_pallas oracle, zero replica-crossing
# collectives, and the launch counts — structural (jaxpr == contract)
# AND per-layer physical (scan-trip-weighted: 1 fwd + 2 bwd per layer).
cfg_fp = cfg.with_(attn_impl="flash_pallas")
lm_fp = build_model(cfg_fp)
flash_train = mk_train(lm_fp, rules, hwa_cfg)
flash_train_c = flash_train.lower(mesh).compile()
fp_inner, fp_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
with use_mesh(mesh):
    for step in range(N_STEPS):
        fp_inner, fp_opt, fp_losses = flash_train_c(fp_inner, fp_opt,
                                                    batches(step))
check("flash-pallas train: finite per-replica losses",
      bool(jnp.all(jnp.isfinite(fp_losses))))


def one_fp(p, o, b):
    (l, m), g = jax.value_and_grad(
        lambda q: lm_fp.loss(q, b), has_aux=True)(p)
    upd, o2 = opt.update(g, o, p, LR)
    return apply_updates(p, upd), o2, l


cfp_inner, cfp_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
for step in range(N_STEPS):
    cfp_inner, cfp_opt, _ = jax.vmap(one_fp)(cfp_inner, cfp_opt,
                                             batches(step))
err_fp = tree_err(fp_inner, cfp_inner)
check(f"flash-pallas train == single-device oracle after {N_STEPS} steps "
      f"(err={err_fp:.2e})", err_fp < 1e-5)

flash_hlo = flash_train_c.as_text()
cross_fp = collectives_crossing_axis(flash_hlo, mesh, "replica")
check(f"flash-pallas train: zero replica-crossing collectives "
      f"(found {len(cross_fp)})", len(cross_fp) == 0)

fp_jaxpr = jax.make_jaxpr(flash_train.fn)(*flash_train.abstract_args)
n_struct = count_pallas_calls(fp_jaxpr)
fp_budget = flash_train.contract.launch
check(f"flash-pallas train: structural jaxpr launches == LaunchBudget "
      f"({n_struct} == [{fp_budget.min}, {fp_budget.max}])",
      fp_budget is not None and fp_budget.min == n_struct == fp_budget.max)


def physical_launches(jaxpr):
    """Scan-trip-weighted launch count: the layer scan is one jaxpr eqn,
    but each trip is a real launch at run time."""
    while hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
            continue
        mult = (eqn.params.get("length", 1)
                if eqn.primitive.name == "scan" else 1)
        for param in eqn.params.values():
            for sub in (param if isinstance(param, (list, tuple))
                        else (param,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += mult * physical_launches(sub)
    return n


n_phys = physical_launches(fp_jaxpr)
check(f"flash-pallas train: 1 fwd + 2 bwd launches per layer "
      f"({n_phys} == 3 × {cfg.n_layers})", n_phys == 3 * cfg.n_layers)

# ---- compressed WA precision: bounded-ULP parity (PR 10) ------------------
# The compressed legs reuse the exact-f32 results above as oracles and
# bound the deviation in RELATIVE ULPs of the compressed dtype at the
# buffer's working scale (repro.common.quant.rel_ulp_error). Budgets come
# from benchmarks/thresholds.json `ulp_budgets` — the SAME numbers
# bench-check guards, so the harness and the bench trajectory cannot
# drift apart. (The f32 default's 0-ULP guarantee is every bit-equality
# check above.)
import json

from repro.common.quant import rel_ulp_error
from repro.launch.steps import window_state_args

with open(os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))),
        "benchmarks", "thresholds.json")) as f:
    ULP_BUDGETS = json.load(f)["ulp_budgets"]


def max_rel_ulp(ref, got, tok):
    return max(rel_ulp_error(r, g, tok)
               for r, g in zip(jax.tree.leaves(ref), jax.tree.leaves(got)))


# leg C-bf16: flat kernel sync with a bf16 ring (Kahan-compensated f32
# total) on the SAME diverged state as the f32 kernel leg. The restart
# is the DECODED stored mean (packed.py: ring slot and live replicas
# agree bitwise), so it must be exactly the bf16-rounding of the f32
# leg's restart; W̿ reads back through the compressed ring and gets the
# bf16 budget.
from repro.common.quant import decode_slot, encode_slot

sync_bf = mk_sync(lm, rules, hwa_cfg_k, wa_dtype="bf16")
win_bf = window_state_args(sync_bf)
nb = len(win_bf) - 3                      # ring, [scales], ..., [comp]
with use_mesh(mesh):
    out_bf = sync_bf.lower(mesh).compile()(
        jax.tree.map(jnp.array, a_host), *win_bf)
bf_inner, bf_wa = out_bf[0], out_bf[3 + nb]
check("bf16-ring kernel sync: restart == bf16-rounded f32 restart "
      "(ring slot and replicas agree bitwise)",
      tree_equal(bf_inner, jax.tree.map(
          lambda x: decode_slot(encode_slot(x, "bf16")[0]), k_inner)))
err_bf = max_rel_ulp(k_wa, bf_wa, "bf16")
check(f"bf16-ring kernel sync: W̿ within {ULP_BUDGETS['bf16']} rel ULPs "
      f"of exact f32 (err={err_bf:.2f})", err_bf <= ULP_BUDGETS["bf16"])

# leg C-fp8: the full compressed tree — fp8 ring (per-block scales) AND
# fp8 cross-pod comms — against the exact f32 tree leg T.
sync_f8 = build_hwa_bundles(
    lm, rules_t, SyncPlan(hwa=hwa4, topology=topo,
                          wa_dtype="fp8", comms_dtype="fp8")).sync
win_f8 = window_state_args(sync_f8)
nf = len(win_f8) - 3
f8_c = sync_f8.lower(mesh_t).compile()
with use_mesh(mesh_t):
    out_f8 = f8_c(jax.tree.map(jnp.array, div4_host), *win_f8)
err_f8 = max_rel_ulp(t_wa, out_f8[3 + nf], "fp8")
check(f"fp8 tree sync (fp8 ring + fp8 comms): W̿ within "
      f"{ULP_BUDGETS['fp8']} rel ULPs of exact f32 (err={err_f8:.2f})",
      err_f8 <= ULP_BUDGETS["fp8"])
audit_f8 = sync_collective_audit(f8_c.as_text(), mesh_t,
                                 replica_axis="replica", outer_axis="pod")
check(f"fp8 tree sync: outer hop is the gather pair "
      f"(found {len(audit_f8['outer'])})", len(audit_f8["outer"]) == 2)
check("fp8 tree sync: compressed payload crosses the wire as u8",
      any("u8[" in line for _, line in audit_f8["outer"]))

print("ALL_OK" if ok else "SOME_FAILED")
raise SystemExit(0 if ok else 1)
