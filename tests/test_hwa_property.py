"""Property-based tests (hypothesis) on the weight-averaging invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis-heavy: excluded from the CI tier1 PR lane (-m "not slow");
# the nightly full lane runs it
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.common.pytree import tree_mean_axis0, tree_stack
from repro.core import (broadcast_to_replicas, online_average, window_init,
                        window_update)

SETTINGS = dict(max_examples=15, deadline=None)


def _tree(seed, scale=1.0):
    k = jax.random.key(seed)
    k1, k2 = jax.random.split(k)
    return {"a": scale * jax.random.normal(k1, (3, 5)),
            "b": scale * jax.random.normal(k2, (4,))}


@given(st.integers(2, 5), st.integers(0, 1000))
@settings(**SETTINGS)
def test_online_average_permutation_invariant(k, seed):
    trees = [_tree(seed + i) for i in range(k)]
    perm = np.random.RandomState(seed).permutation(k)
    a = online_average(tree_stack(trees))
    b = online_average(tree_stack([trees[i] for i in perm]))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


@given(st.integers(2, 5), st.integers(0, 100))
@settings(**SETTINGS)
def test_average_of_identical_replicas_is_identity(k, seed):
    t = _tree(seed)
    stacked = broadcast_to_replicas(t, k)
    avg = online_average(stacked)
    for x, y in zip(jax.tree.leaves(avg), jax.tree.leaves(t)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


@given(st.integers(1, 6), st.integers(1, 12), st.integers(0, 50))
@settings(**SETTINGS)
def test_window_equals_bruteforce(window, n_updates, seed):
    ws = window_init(_tree(seed), window)
    outers = [_tree(seed + 10 + t) for t in range(n_updates)]
    wa = None
    for t, o in enumerate(outers):
        ws, wa = window_update(ws, o)
    lo = max(0, n_updates - window)
    expect = tree_mean_axis0(tree_stack(outers[lo:]))
    for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(expect)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@given(st.integers(2, 4), st.floats(0.1, 10.0), st.integers(0, 50))
@settings(**SETTINGS)
def test_online_average_linearity(k, scale, seed):
    """mean(c·W) = c·mean(W) — scaling commutes with the averaging."""
    trees = [_tree(seed + i) for i in range(k)]
    a = online_average(tree_stack([jax.tree.map(lambda x: scale * x, t)
                                   for t in trees]))
    b = jax.tree.map(lambda x: scale * x, online_average(tree_stack(trees)))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)


@given(st.integers(1, 5), st.integers(0, 30))
@settings(**SETTINGS)
def test_window_average_bounded_by_extremes(window, seed):
    """Every coordinate of W̿ lies within [min, max] of the window entries."""
    ws = window_init(_tree(seed), window)
    entries = []
    wa = None
    for t in range(window):
        o = _tree(seed + 100 + t)
        entries.append(o)
        ws, wa = window_update(ws, o)
    for key in ("a", "b"):
        stack = np.stack([np.asarray(e[key]) for e in entries])
        assert np.all(np.asarray(wa[key]) <= stack.max(0) + 1e-5)
        assert np.all(np.asarray(wa[key]) >= stack.min(0) - 1e-5)
