"""Sync-topology subsystem unit tests (no forced devices needed).

Covers the pieces of ``launch/sync/`` that are pure structure or pure
math: topology validation/scheduling, the 0-ULP grouped-mean property
(hypothesis, every factorization of a power-of-two K), the extended
``sync_collective_audit`` per-level verdicts — including rejection of a
deliberately-miswired grouping — and the legacy-assembly hard error +
escape hatch. The mesh-executed counterparts run in the subprocess suite
(tests/mesh_hwa_check.py).
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.online import (halving_sum_axis0, online_average,
                               online_average_canonical,
                               online_average_grouped, pod_mean_grouped)
from repro.launch.hlo import sync_collective_audit
from repro.launch.sync.legacy import ALLOW_ENV, check_legacy_assembly
from repro.launch.sync.topology import Flat, TwoLevel


# --------------------------------------------------------------- topology


def _fake_mesh(shape: dict):
    dims = tuple(shape.values())
    return types.SimpleNamespace(shape=shape, axis_names=tuple(shape),
                                 devices=np.empty(dims),
                                 size=int(np.prod(dims)))


def test_flat_topology_axes_and_validation():
    mesh = _fake_mesh({"replica": 2, "data": 2, "model": 2})
    flat = Flat("replica")
    assert flat.replica_axes == ("replica",)
    assert flat.n_replicas(mesh) == 2
    assert flat.psum_groups() == (("replica",),)
    assert flat.is_outer(0) and flat.is_outer(7)
    flat.validate(mesh, 2)
    with pytest.raises(ValueError, match="K == replica-axis size"):
        flat.validate(mesh, 4)
    with pytest.raises(ValueError, match="not in mesh"):
        Flat("pod").validate(mesh, 2)
    joint = Flat(("replica", "data"))
    assert joint.n_replicas(mesh) == 4
    joint.validate(mesh, 4)


def test_two_level_topology_structure():
    mesh = _fake_mesh({"pod": 2, "replica": 4, "model": 2})
    topo = TwoLevel("replica", "pod", outer_every=3)
    assert topo.replica_axes == ("pod", "replica")   # pod-major
    assert topo.n_replicas(mesh) == 8
    assert topo.pods(mesh) == 2 and topo.pod_size(mesh) == 4
    assert topo.psum_groups() == (("replica",), ("pod",))
    assert topo.inner_groups() == (("replica",),)
    topo.validate(mesh, 8)
    with pytest.raises(ValueError, match="pods × pod_size"):
        topo.validate(mesh, 4)
    with pytest.raises(ValueError, match="must differ"):
        TwoLevel("replica", "replica").validate(mesh, 8)
    with pytest.raises(ValueError, match="outer_every"):
        TwoLevel("replica", "pod", outer_every=0).validate(mesh, 8)


def test_two_level_outer_schedule():
    topo = TwoLevel("replica", "pod", outer_every=3)
    # the H₂-th, 2·H₂-th, ... syncs are outer (0-based index)
    assert [topo.is_outer(i) for i in range(7)] == \
        [False, False, True, False, False, True, False]
    assert all(TwoLevel("replica", "pod", outer_every=1).is_outer(i)
               for i in range(4))
    # traced index works too (the driver may carry it as an int32)
    assert bool(topo.is_outer(jnp.asarray(2, jnp.int32)))
    assert not bool(topo.is_outer(jnp.asarray(3, jnp.int32)))


# ------------------------------------------------- grouped means (0 ULP)


def _tree(seed, k):
    ks = jax.random.split(jax.random.key(seed), 2)
    return {"w": jax.random.normal(ks[0], (k, 3, 5)),
            "b": jax.random.normal(ks[1], (k, 7))}


def test_halving_sum_matches_sum():
    for n in (1, 2, 3, 5, 8):
        x = jax.random.normal(jax.random.key(n), (n, 4))
        np.testing.assert_allclose(np.asarray(halving_sum_axis0(x)),
                                   np.asarray(x).sum(0), rtol=1e-6,
                                   atol=1e-6)


def test_grouped_mean_rejects_bad_factorization():
    t = _tree(0, 6)
    with pytest.raises(ValueError, match="do not divide"):
        online_average_grouped(t, 4)
    with pytest.raises(ValueError, match="do not divide"):
        pod_mean_grouped(t, 5)


def test_pod_mean_grouped_shape_and_values():
    t = _tree(3, 4)
    pm = pod_mean_grouped(t, 2)
    assert pm["w"].shape == (2, 3, 5)
    np.testing.assert_allclose(np.asarray(pm["w"][0]),
                               np.asarray(t["w"][:2]).mean(0), rtol=1e-6)


def _assert_grouped_matches_flat(k, seed, pods_list=None):
    t = _tree(seed, k)
    flat = online_average_canonical(t)
    pods_list = pods_list or [d for d in range(1, k + 1) if k % d == 0]
    for pods in pods_list:
        grouped = online_average_grouped(t, pods)
        for a, b in zip(jax.tree.leaves(grouped), jax.tree.leaves(flat)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"K={k} pods={pods}: grouped mean differs from flat"


@pytest.mark.parametrize("k", [2, 4, 8, 16])
@pytest.mark.parametrize("seed", [0, 7, 123])
def test_two_level_grouped_mean_is_flat_mean_0ulp(k, seed):
    """TwoLevel grouped averaging matches the flat K-replica mean
    BIT-EXACTLY for every valid (pods × per-pod) factorization of K. For
    power-of-two K every divisor qualifies (each factorization has
    power-of-two group sizes, so the grouped halving sums compose into
    exactly the flat halving tree). Deterministic leg of the property;
    the hypothesis leg below widens the seed space when available."""
    _assert_grouped_matches_flat(k, seed)


@pytest.mark.parametrize("pods,per", [(3, 4), (5, 2), (6, 8)])
def test_grouped_mean_0ulp_for_pow2_pods_of_any_count(pods, per):
    """The composition property needs only the GROUP SIZE to be a power
    of two — the pod count may be odd (the halving tree peels the odd
    trailing partial identically on both sides)."""
    _assert_grouped_matches_flat(pods * per, seed=42, pods_list=[pods])


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(st.sampled_from([2, 4, 8, 16]), st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_two_level_grouped_mean_property(k, seed):
        """Hypothesis-widened version of the 0-ULP property over random
        replica populations, every factorization of K."""
        _assert_grouped_matches_flat(k, seed)


def test_canonical_mean_close_to_plain_mean():
    t = _tree(9, 8)
    a = online_average_canonical(t)
    b = online_average(t)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------- per-level audit on synthetic HLO
#
# A (2,2,2) (pod, replica, model) mesh: logical device index =
# pod·4 + replica·2 + model. Inner (per-pod) groups pair devices
# differing only in the replica coordinate; outer (cross-pod) groups
# differ only in pod; a MISWIRED joint grouping spans both.

_MESH = _fake_mesh({"pod": 2, "replica": 2, "model": 2})
_INNER_AR = ('  %ar.0 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), '
             'replica_groups={{0,2},{1,3},{4,6},{5,7}}, to_apply=%add')
_OUTER_AR = ('  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %ar.0), '
             'replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add')
_JOINT_AR = ('  %ar.2 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), '
             'replica_groups={{0,2,4,6},{1,3,5,7}}, to_apply=%add')
_MODEL_AR = ('  %ar.3 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), '
             'replica_groups={{0,1},{2,3},{4,5},{6,7}}, to_apply=%add')


def _audit(*lines):
    return sync_collective_audit("\n".join(lines), _MESH,
                                 replica_axis="replica", outer_axis="pod")


def test_audit_accepts_inner_only_sync():
    a = _audit(_INNER_AR)
    assert a["inner_sync_ok"] and not a["outer_sync_ok"]
    assert len(a["replica"]) == 1 and not a["outer"] and not a["mixed"]
    assert a["assembly_free"]


def test_audit_accepts_outer_sync_composition():
    a = _audit(_INNER_AR, _OUTER_AR)
    assert a["outer_sync_ok"] and not a["inner_sync_ok"]
    assert len(a["outer"]) == 1 and not a["mixed"]


def test_audit_rejects_miswired_joint_grouping():
    """A joint all-reduce whose groups span pods AND pod members is not
    a valid realization of either tree level."""
    a = _audit(_JOINT_AR)
    assert a["mixed"] and not a["inner_sync_ok"] and not a["outer_sync_ok"]
    # ... nor does sneaking it in next to the proper composition help
    b = _audit(_INNER_AR, _OUTER_AR, _JOINT_AR)
    assert not b["inner_sync_ok"] and not b["outer_sync_ok"]


def test_audit_flags_assembly_traffic():
    a = _audit(_INNER_AR, _MODEL_AR)
    assert not a["assembly_free"]
    assert not a["inner_sync_ok"] and not a["outer_sync_ok"]


def test_audit_flat_compat_keys():
    """Pre-split callers use replica_allreduce_only/assembly_free with no
    outer axis; the extended audit must keep those semantics."""
    a = sync_collective_audit(_INNER_AR, _MESH, replica_axis="replica")
    assert a["replica_allreduce_only"] and a["assembly_free"]
    assert a["outer"] == [] and a["mixed"] == []


def test_check_outer_every_refuses_disagreement():
    """H₂ has one source of truth: a config that disagrees with the
    topology (or would be silently ignored by a flat builder) raises."""
    from repro.core.hwa import HWAConfig
    from repro.launch.sync.bundles import _check_outer_every

    topo = TwoLevel("replica", "pod", outer_every=2)
    _check_outer_every(HWAConfig(outer_every=2), topo)
    _check_outer_every(HWAConfig(), Flat())
    with pytest.raises(ValueError, match="disagrees"):
        _check_outer_every(HWAConfig(outer_every=3), topo)
    with pytest.raises(ValueError, match="silently ignored"):
        _check_outer_every(HWAConfig(outer_every=2), Flat())


# ------------------------------------------- legacy-assembly hard error


def test_legacy_assembly_hard_error_and_escape_hatch(monkeypatch):
    mesh = _fake_mesh({"replica": 2, "data": 2, "model": 2})
    monkeypatch.delenv(ALLOW_ENV, raising=False)
    # this suite runs on the CPU backend — the dangerous configuration
    with pytest.raises(RuntimeError, match="MISCOMPILED"):
        check_legacy_assembly(mesh)
    # escape hatch downgrades to the loud PR-3 warning
    monkeypatch.setenv(ALLOW_ENV, "1")
    with pytest.warns(RuntimeWarning, match="MISCOMPILED"):
        check_legacy_assembly(mesh)
    # single device: never dangerous
    monkeypatch.delenv(ALLOW_ENV, raising=False)
    check_legacy_assembly(_fake_mesh({"data": 1}))
    # non-CPU backends lower the pattern correctly: no raise
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    check_legacy_assembly(mesh)


# --------------------------------------------------------------- SyncPlan


def test_sync_plan_tokens_and_resolved_topology():
    from repro.core.hwa import HWAConfig
    from repro.launch.sync.plan import SyncPlan
    hwa = HWAConfig(n_replicas=4, window=3, outer_every=2)
    tree = TwoLevel("replica", "pod", outer_every=2)
    plan = SyncPlan(hwa=hwa, topology=tree, wa_dtype=jnp.bfloat16,
                    comms_dtype=jnp.float8_e4m3fn)
    # dtype arguments normalize to tokens at construction
    assert plan.wa_dtype == "bf16" and plan.comms_dtype == "fp8"
    assert plan.is_tree and plan.resolved_topology is tree
    flat = SyncPlan(hwa=hwa)
    assert not flat.is_tree
    assert isinstance(flat.resolved_topology, Flat)
    assert flat.resolved_topology.replica_axes == ("replica",)


def test_sync_plan_rejects_invalid_corners():
    from repro.core.hwa import HWAConfig
    from repro.launch.sync.plan import SyncPlan
    hwa = HWAConfig(n_replicas=4, window=3)
    tree = TwoLevel("replica", "pod", outer_every=2)
    # compressed comms need a two-level outer hop to compress
    with pytest.raises(ValueError, match="no outer level"):
        SyncPlan(hwa=hwa, comms_dtype="bf16")
    # resilient renormalizes AFTER the psum — incompatible with a
    # pre-scaled quantized payload
    import dataclasses
    with pytest.raises(ValueError, match="resilient"):
        SyncPlan(hwa=dataclasses.replace(hwa, resilient=True,
                                         outer_every=2),
                 topology=tree, comms_dtype="fp8")
    # the tree is mesh-native only
    with pytest.raises(ValueError, match="mesh-native"):
        SyncPlan(hwa=dataclasses.replace(hwa, outer_every=2),
                 topology=tree, mesh_native=False)
    # unknown precision tokens fail at construction, not deep in a builder
    with pytest.raises(ValueError, match="precision token"):
        SyncPlan(hwa=hwa, wa_dtype="int4")


def test_deprecated_builder_names_warn_and_delegate():
    """The five historical make_*hwa*_step names survive as thin wrappers
    that warn; the bundles they return come from the same private
    builders build_hwa_bundles drives."""
    import warnings

    from jax.sharding import Mesh

    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch import steps
    from repro.models.registry import build_model
    from repro.sharding.rules import make_tp_rules

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("replica", "data", "model"))
    lm = build_model(get_smoke_config("granite-3-2b"))
    rules = make_tp_rules(mesh, replica_axis="replica")
    hwa = HWAConfig(n_replicas=2, window=2)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        bundle = steps.make_hwa_sync_step(lm, rules, hwa)
    assert any(issubclass(w.category, DeprecationWarning)
               and "build_hwa_bundles" in str(w.message) for w in caught)
    assert bundle.pack_spec is not None
