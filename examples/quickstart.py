"""Quickstart: train a small LM with HWA and compare against plain cosine
SGD in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.models.types import ModelConfig
from repro.train import TrainConfig, Trainer, lm_task


def main():
    cfg = ModelConfig(name="quickstart-lm", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=64, attn_impl="naive", remat="none",
                      dtype="float32")
    lm = build_model(cfg)
    ds = make_markov_lm_dataset(vocab=64, seq_len=48, n_train=512,
                                n_test=128, seed=0)
    results = {}
    for method, k in [("ca", 1), ("hwa", 2)]:
        pipe = DataPipeline(ds, batch_size=8, n_replicas=k, seed=0)
        tc = TrainConfig(
            method=method, total_steps=192, batch_size=8, base_lr=0.5,
            eval_every=64,
            hwa=HWAConfig(n_replicas=k, sync_period=0, window=3))
        out = Trainer(lm_task(lm, pipe), tc).run(log=True)
        results[method] = out["best"]
    print("\n=== quickstart summary ===")
    for m, best in results.items():
        print(f"  {m:4s}: best test acc {best['test_acc']:.4f} "
              f"loss {best['test_loss']:.4f}")
    print("HWA (K=2 replicas, H=1 epoch, I=3) vs cosine-SGD baseline.")


if __name__ == "__main__":
    main()
