"""End-to-end driver: train an assigned-architecture LM with HWA.

Default runs the granite-3-2b *smoke* variant for a few hundred steps on
CPU (the full config is exercised via the multi-pod dry-run). On real
hardware, pass --full to build the exact assigned config — the same
Trainer/HWA code paths run under the HWA mesh
(``repro.launch.mesh.make_hwa_mesh``); see src/repro/launch/steps.py for
the pjit step builders the launcher uses at scale.

  PYTHONPATH=src python examples/train_lm_hwa.py --arch xlstm-125m \
      --steps 300 --k 2 --window 10
"""
import argparse

from repro.checkpoint import OuterWeightStore, save_pytree
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.train import TrainConfig, Trainer, lm_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--sync-period", type=int, default=0)
    ap.add_argument("--window", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--full", action="store_true",
                    help="exact assigned config (needs real accelerators)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("modality archs: see examples/serve_decode.py")
    lm = build_model(cfg)
    ds = make_markov_lm_dataset(vocab=cfg.vocab_size, seq_len=args.seq_len,
                                n_train=2048, n_test=512, seed=0)
    pipe = DataPipeline(ds, batch_size=args.batch_size, n_replicas=args.k,
                        seed=0)
    tc = TrainConfig(method="hwa", total_steps=args.steps,
                     batch_size=args.batch_size, base_lr=args.lr,
                     hwa=HWAConfig(n_replicas=args.k,
                                   sync_period=args.sync_period,
                                   window=args.window))
    out = Trainer(lm_task(lm, pipe), tc).run(log=True)
    print(f"[{args.arch}] final: {out['final']}  best: {out['best']}")
    if args.ckpt_dir:
        save_pytree(f"{args.ckpt_dir}/wa_final.npz", out["params"])
        print(f"saved W̿ to {args.ckpt_dir}/wa_final.npz")


if __name__ == "__main__":
    main()
