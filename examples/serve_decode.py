"""Batched serving across architecture families — KV-cache decode for a
dense LM, SSM-state decode for xLSTM, and MusicGen multi-codebook decode
with the delay pattern.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import DecodeEngine, apply_delay_pattern, \
    undo_delay_pattern


def demo(arch, B=4, prompt=16, new=16):
    cfg = get_smoke_config(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    key = jax.random.key(1)
    batch = {}
    if cfg.family == "audio":
        frames = jax.random.randint(key, (B, prompt, cfg.n_codebooks), 0,
                                    cfg.vocab_size)
        batch["tokens"] = apply_delay_pattern(frames)[:, :prompt]
    else:
        batch["tokens"] = jax.random.randint(key, (B, prompt), 0,
                                             cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            key, (B, cfg.n_vis_tokens, cfg.d_vis), jnp.float32)
    engine = DecodeEngine(lm, params, max_seq_len=prompt + new)
    t0 = time.time()
    out = engine.generate(batch, new, temperature=0.8, seed=0)
    dt = time.time() - t0
    extra = ""
    if cfg.family == "audio":
        frames = undo_delay_pattern(out, new - cfg.n_codebooks + 1)
        extra = f" -> {frames.shape} undelayed frames"
    print(f"[{arch:18s}] generated {tuple(out.shape)} in {dt:5.2f}s "
          f"({B * new / dt:6.1f} tok/s){extra}")


def main():
    for arch in ["granite-3-2b", "xlstm-125m", "hymba-1.5b",
                 "internvl2-1b", "musicgen-medium"]:
        demo(arch)


if __name__ == "__main__":
    main()
