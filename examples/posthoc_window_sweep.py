"""Paper §III-B: with sufficient budget, try multiple window lengths I
*post hoc* from saved outer-weight checkpoints — no retraining.

Trains once with HWA saving W̄_e to an OuterWeightStore each cycle, then
sweeps I ∈ {1..n_cycles} (and a sparse stride-2 window) offline,
evaluating each candidate W̿ on the test split.

  PYTHONPATH=src python examples/posthoc_window_sweep.py
"""
import tempfile

import jax

from repro.checkpoint import OuterWeightStore
from repro.core import HWAConfig, hwa_init, hwa_inner_step, hwa_sync
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.models.types import ModelConfig
from repro.optim import cosine_schedule, sgd

CFG = ModelConfig(name="sweep-lm", family="dense", n_layers=2, d_model=48,
                  n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=64,
                  attn_impl="naive", remat="none", dtype="float32")


def main():
    lm = build_model(CFG)
    ds = make_markov_lm_dataset(vocab=64, seq_len=48, n_train=256,
                                n_test=128, seed=0)
    pipe = DataPipeline(ds, batch_size=8, n_replicas=2, seed=0)
    H = pipe.steps_per_epoch
    total_cycles = 12
    hcfg = HWAConfig(n_replicas=2, sync_period=H, window=1)
    opt = sgd(momentum=0.9, weight_decay=5e-4)
    sched = cosine_schedule(0.5, H * total_cycles)

    def loss_fn(params, batch):
        return lm.loss(params, {"tokens": batch[0], "targets": batch[1]})

    state = hwa_init(hcfg, lm.init(jax.random.key(0)), opt)
    step_fn = jax.jit(lambda st, i: hwa_inner_step(
        hcfg, st, pipe.stacked_batch(i), loss_fn, opt, sched(i)))
    sync_fn = jax.jit(lambda st: hwa_sync(hcfg, st))

    with tempfile.TemporaryDirectory() as tmp:
        store = OuterWeightStore(tmp)
        for i in range(H * total_cycles):
            state, _ = step_fn(state, i)
            if (i + 1) % H == 0:
                state, _ = sync_fn(state)
                cycle = int(state.cycle)
                # the post-sync inner weights ARE the outer weights
                outer = jax.tree.map(lambda x: x[0], state.inner)
                store.save(cycle, outer)
        print(f"saved {len(store.cycles())} outer checkpoints")

        @jax.jit
        def test_loss(params):
            l, m = lm.loss(params, {"tokens": ds.test_inputs,
                                    "targets": ds.test_targets})
            return m["loss"], m["acc"]

        like = jax.tree.map(lambda x: x[0], state.inner)
        end = store.cycles()[-1]
        print(f"{'window':>8s} {'stride':>7s} {'test loss':>10s} "
              f"{'test acc':>9s}")
        best = (None, float("inf"))
        for stride in (1, 2):
            for window in (1, 2, 4, 8, 12):
                if window * stride > total_cycles:
                    continue
                wa = store.window_average(end, window, like, stride=stride)
                l, a = test_loss(wa)
                print(f"{window:8d} {stride:7d} {float(l):10.4f} "
                      f"{float(a):9.4f}")
                if float(l) < best[1]:
                    best = ((window, stride), float(l))
        print(f"best window (I, stride) = {best[0]} "
              f"with test loss {best[1]:.4f} — chosen post hoc, "
              f"no retraining (paper §III-B).")


if __name__ == "__main__":
    main()
