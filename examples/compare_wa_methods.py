"""Side-by-side comparison of every weight-averaging method the paper
discusses (its Table II protocol at CPU scale).

  PYTHONPATH=src python examples/compare_wa_methods.py --steps 256
"""
import argparse

from benchmarks.common import run_method


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=256)
    args = ap.parse_args()
    print(f"{'method':12s} {'best acc':>9s} {'best loss':>10s} "
          f"{'final loss':>11s} {'s/step':>7s}")
    for method in ["base", "ca", "swa", "ema", "lookahead", "sam",
                   "online", "pmsgd", "hwa"]:
        out = run_method(method, steps=args.steps)
        print(f"{method:12s} {out['best']['test_acc']:9.4f} "
              f"{out['best']['test_loss']:10.4f} "
              f"{out['final']['test_loss']:11.4f} "
              f"{out['seconds'] / args.steps:7.3f}")


if __name__ == "__main__":
    main()
