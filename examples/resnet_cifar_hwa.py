"""Paper-faithful pipeline: ResNet-CIFAR + BatchNorm + SGD(momentum 0.9,
wd 5e-4) + cosine LR + HWA with H = one epoch — including Algorithm 2's
BatchNorm-statistics recompute on the averaged weights.

This is the paper's own experimental protocol transplanted onto the
synthetic prototype-image task (offline container; DESIGN.md §8).

  PYTHONPATH=src python examples/resnet_cifar_hwa.py --epochs 6
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import HWAConfig, hwa_init, hwa_inner_step, hwa_sync
from repro.core.bnstats import recompute_bn_stats
from repro.data import make_prototype_image_dataset
from repro.data.pipeline import replica_batch_indices
from repro.models.convnet import (apply_resnet, init_resnet, resnet_loss,
                                  resnet_cifar_config)
from repro.optim import cosine_schedule, sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--window", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=32)
    args = ap.parse_args()

    cfg = resnet_cifar_config(depth=args.depth, n_classes=10, image_size=16)
    ds = make_prototype_image_dataset(n_classes=10, image_size=16,
                                      n_train=2048, n_test=512, noise=0.6,
                                      label_noise=0.05)
    steps_per_epoch = ds.n_train // args.batch_size
    total_steps = steps_per_epoch * args.epochs
    opt = sgd(momentum=0.9, weight_decay=5e-4)
    sched = cosine_schedule(0.1, total_steps)
    hcfg = HWAConfig(n_replicas=args.k, sync_period=steps_per_epoch,
                     window=args.window)

    params, bn_state = init_resnet(cfg, jax.random.key(0))
    # fold BN state into the averaged pytree (stats are averaged online;
    # the W̿ stats get recomputed per Algorithm 2 line 3)
    state = hwa_init(hcfg, {"p": params, "bn": bn_state}, opt)
    data_key = jax.random.key(1)

    def loss_fn(bundle, batch):
        loss, metrics = resnet_loss(cfg, bundle["p"], bundle["bn"], batch)
        return loss, metrics

    @jax.jit
    def inner(state, step):
        def batch_for(r):
            idx = replica_batch_indices(data_key, r, step, ds.n_train,
                                        args.batch_size)
            return {"tokens": jnp.take(ds.train_inputs, idx, 0),
                    "targets": jnp.take(ds.train_targets, idx, 0)}
        batches = jax.vmap(batch_for)(jnp.arange(args.k))
        state, metrics = hwa_inner_step(hcfg, state, batches, loss_fn, opt,
                                        sched(step))
        return state, metrics["loss"]

    @jax.jit
    def evaluate(bundle):
        logits, _ = apply_resnet(cfg, bundle["p"], bundle["bn"],
                                 ds.test_inputs, train=False)
        return jnp.mean((jnp.argmax(logits, -1) == ds.test_targets)
                        .astype(jnp.float32))

    for step in range(total_steps):
        state, loss = inner(state, step)
        if (step + 1) % steps_per_epoch == 0:
            state, m = hwa_sync(hcfg, state)
            wa = state.wa
            # Algorithm 2 line 3: recompute BN statistics under W̿
            bn = recompute_bn_stats(
                cfg, wa["p"], wa["bn"],
                [ds.train_inputs[i:i + 256]
                 for i in range(0, 1024, 256)])
            acc = evaluate({"p": wa["p"], "bn": bn})
            print(f"epoch {(step + 1) // steps_per_epoch}: "
                  f"train loss {float(loss):.4f}  "
                  f"W̿ test acc {float(acc):.4f}  "
                  f"replica divergence {float(m['replica_divergence']):.3f}")


if __name__ == "__main__":
    main()
