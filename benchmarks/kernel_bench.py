"""Kernel micro-benchmarks.

Wall-times are the jit'd XLA *reference* implementations on CPU (the
Pallas kernels run in interpret mode here — TPU is the target, so their
value is the HBM-traffic model, reported as derived columns):

  fused wa_window_update : 3 reads + 3 writes vs naive 6 reads + 3 writes
  fused sync             : (K+2) reads + 3 writes vs (K+3) reads + 4 writes
  online_mean            : K reads + 1 write (fused cast)

The packed-vs-per-leaf comparison drives a transformer-like tree
(≥100 leaves, mixed 128-element biases and 1M-element matrices) through
both WA-update formulations and reports, per path: kernel-launch count
(structural, from the jaxpr), padding waste (bytes padded / bytes
useful), and ref-impl wall time.

The gated-vs-mesh-resident comparison (subprocess, 8 forced host
devices, (2,2,2) replica/data/model mesh) lowers the mesh sync bundle
both ways and reports, per path: Pallas launches, collective counts and
modeled per-device ICI bytes per sync split into the replica-axis weight
all-reduce vs packed-W̄ assembly traffic — the cost the shard-aware
layout removes. ``benchmarks.run`` tees the returned dict into
BENCH_kernels.json at the repo root for cross-PR tracking.
"""
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

# CI bench-smoke lane: shrink the buffers/tree/attention so the suite
# runs in seconds while every STRUCTURAL metric (launch counts,
# collective counts, padding-waste order) keeps the same contract —
# tools/bench_check.py guards exactly those, never wall times.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

from repro.common.packing import ALIGN, pack, pack_spec, pack_stacked
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.launch.hlo import count_pallas_calls
from benchmarks.common import csv_row


def _time(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))     # warm up with ONE call
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def transformer_like_tree(key=0):
    """≥100 leaves with a transformer's size mix: a few 1M-element
    matrices, mid-size projections, and many 128-element biases (the
    SMOKE lane keeps the mix but shrinks every class)."""
    ks = iter(jax.random.split(jax.random.key(key), 128))
    n_embed, embed_shape, n_proj, n_bias = \
        (2, (256, 512), 10, 20) if SMOKE else (2, (1024, 1024), 30, 70)
    tree = {}
    for i in range(n_embed):
        tree[f"embed_{i}"] = jax.random.normal(next(ks), embed_shape)
    for i in range(n_proj):
        tree[f"proj_{i}"] = jax.random.normal(next(ks), (128, 512))
    for i in range(n_bias):
        tree[f"bias_{i}"] = jax.random.normal(next(ks), (128,))
    return tree


def _per_leaf_pad_waste(tree):
    useful = padded = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        useful += n
        padded += -(-n // ALIGN) * ALIGN
    return (padded - useful) / useful


def packed_vs_per_leaf(print_fn=print):
    I, K = 4, 2
    tree = transformer_like_tree()
    n_leaves = len(jax.tree.leaves(tree))
    spec = pack_spec(tree)

    # --- launch counts (structural: pallas_call eqns in the jaxpr) ------
    def per_leaf_update(ring, total, new):
        triples = jax.tree.map(
            lambda r, t, n: kops.wa_window_update(r, t, n, 0, 1.0, 1.0 / I),
            ring, total, new)
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        return jax.tree.map(lambda t: t[1], triples, is_leaf=is3)

    ring_tree = jax.tree.map(lambda x: jnp.zeros((I,) + x.shape), tree)
    total_tree = jax.tree.map(jnp.zeros_like, tree)
    launches_per_leaf = count_pallas_calls(jax.make_jaxpr(per_leaf_update)(
        ring_tree, total_tree, tree))

    ring = jnp.zeros((I, spec.padded))
    total = jnp.zeros((spec.padded,))
    new = pack(tree, spec)
    launches_packed = count_pallas_calls(jax.make_jaxpr(
        lambda r, t, n: kops.wa_window_update_packed(r, t, n, 0, 1.0, 1.0 / I)
    )(ring, total, new))
    stacked = jnp.stack([new, new])
    launches_fused = count_pallas_calls(jax.make_jaxpr(
        lambda s, r, t: kops.hwa_sync_packed(s, r, t, 0, 1.0, 1.0 / I)
    )(stacked, ring, total))

    # --- padding waste --------------------------------------------------
    waste_per_leaf = _per_leaf_pad_waste(tree)
    waste_packed = spec.pad_waste

    # --- wall time: donated steady-state loop (state threaded through,
    # ring/total updated in place — the deployment shape), jit'd refs.
    # On CPU the elementwise work dominates and XLA fuses either way; the
    # launch-count/padding columns above are the TPU-side story.
    idx = jnp.zeros((), jnp.int32)

    def _time_threaded(fn, ring, total, new, iters=10):
        ring, total, avg = fn(ring, total, new)
        jax.block_until_ready((ring, total, avg))
        t0 = time.time()
        for _ in range(iters):
            ring, total, avg = fn(ring, total, new)
            jax.block_until_ready(avg)
        jax.block_until_ready((ring, total))
        return (time.time() - t0) / iters * 1e6

    def leaf_ref(ring, total, new):
        # keep all of (ring', total', avg): dropping any lets XLA DCE
        # that part of the update and skews the timing
        triples = jax.tree.map(
            lambda r, t, n: kref.wa_window_update_ref(r, t, n, idx, 1.0,
                                                      1.0 / I),
            ring, total, new)
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        pick = lambda i: jax.tree.map(lambda t: t[i], triples, is_leaf=is3)
        return pick(0), pick(1), pick(2)

    us_leaf = _time_threaded(jax.jit(leaf_ref, donate_argnums=(0, 1)),
                             ring_tree, total_tree, tree)
    packed_ref = jax.jit(lambda r, t, n: kref.wa_window_update_ref(
        r, t, n, idx, 1.0, 1.0 / I), donate_argnums=(0, 1))
    us_packed = _time_threaded(packed_ref, ring, total, new)
    fused_ref = jax.jit(lambda s, r, t: kref.wa_sync_fused_ref(
        s, r, t, idx, 1.0, 1.0 / I), donate_argnums=(1, 2))
    ring2 = jnp.zeros((I, spec.padded))     # previous buffers were donated
    total2 = jnp.zeros((spec.padded,))
    us_fused = _time_threaded(
        lambda r, t, n: fused_ref(stacked, r, t), ring2, total2, new)

    useful_bytes = 4 * spec.size
    rec = {
        "n_leaves": n_leaves, "window": I, "n_replicas": K,
        "useful_bytes": useful_bytes,
        "launches_per_leaf": launches_per_leaf,
        "launches_packed": launches_packed,
        "launches_fused_sync": launches_fused,
        "pad_waste_per_leaf": waste_per_leaf,
        "pad_waste_packed": waste_packed,
        "us_per_leaf_ref": us_leaf, "us_packed_ref": us_packed,
        "us_fused_sync_ref": us_fused,
    }
    print_fn(csv_row(
        "kernel/packed_vs_per_leaf/launches", 0.0,
        f"leaves={n_leaves};per_leaf={launches_per_leaf};"
        f"packed={launches_packed};fused_sync={launches_fused}"))
    print_fn(csv_row(
        "kernel/packed_vs_per_leaf/pad_waste", 0.0,
        f"per_leaf={waste_per_leaf:.4f};packed={waste_packed:.6f}"))
    print_fn(csv_row("kernel/wa_window_update_per_leaf_ref", us_leaf,
                     f"leaves={n_leaves};bytes={useful_bytes}"))
    print_fn(csv_row("kernel/wa_window_update_packed_ref", us_packed,
                     f"leaves={n_leaves};bytes={useful_bytes}"))
    print_fn(csv_row("kernel/hwa_sync_fused_ref", us_fused,
                     f"K={K};bytes={useful_bytes}"))
    return rec


_WORKER_FLAG = "--mesh-sync-worker"


def _mesh_sync_worker():
    """Runs with 8 forced host devices: lower the mesh sync bundle gated
    (legacy GSPMD fallback) vs mesh-resident and measure the difference."""
    import os

    import jax

    # The gated leg deliberately builds the legacy GSPMD fallback, which
    # is a hard error on multi-device CPU meshes (launch/sync/legacy.py —
    # XLA 0.4.37 miscompiles the assembly). This worker only introspects
    # the lowered HLO and never trusts computed values, so opt into the
    # escape hatch.
    os.environ.setdefault("REPRO_ALLOW_LEGACY_ASSEMBLY", "1")

    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch.hlo import (collective_stats, count_pallas_calls,
                                  result_bytes, sync_collective_audit)
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import make_mesh_hwa_sync_step
    from repro.models.registry import build_model
    from repro.sharding.rules import make_tp_rules

    mesh = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
    rules = make_tp_rules(mesh, replica_axis="replica")
    rules_fsdp = make_tp_rules(mesh, replica_axis="replica", fsdp=True)
    lm = build_model(get_smoke_config("granite-3-2b"))
    out = {}
    # fsdp_grouped: the FSDP mixed data×model tilings through the GROUPED
    # mesh-resident layout — per-group launches (≤ n_groups), still zero
    # assembly collectives (before the grouped chooser this tree was
    # stuck on the legacy path measured by the "gated" leg)
    for name, leg_rules, resident in [("gated", rules, False),
                                      ("mesh_resident", rules, True),
                                      ("fsdp_grouped", rules_fsdp, True)]:
        hwa_cfg = HWAConfig(n_replicas=2, window=3, use_kernels=True)
        bundle = make_mesh_hwa_sync_step(lm, leg_rules, hwa_cfg,
                                         mesh_resident=resident)
        compiled = bundle.lower(mesh).compile()
        hlo = compiled.as_text()
        audit = sync_collective_audit(hlo, mesh)
        assembly = {h for hits in audit["other"].values() for h in hits}
        out[name] = {
            "pallas_launches": count_pallas_calls(
                jax.make_jaxpr(bundle.fn)(*bundle.abstract_args)),
            "collectives": sum(collective_stats(hlo).counts.values()),
            "replica_allreduce_bytes": result_bytes(audit["replica"]),
            "assembly_collectives": len(assembly),
            "assembly_bytes": result_bytes(sorted(assembly)),
            "ici_bytes_per_sync": collective_stats(hlo).traffic_bytes,
            "pack_padded_bytes": 4 * bundle.pack_spec.padded,
            "n_groups": bundle.pack_spec.n_groups,
        }
    print(json.dumps(out))


def gated_vs_mesh_resident(print_fn=print):
    """Subprocess driver (forced host devices must not leak into the
    benchmark process)."""
    from benchmarks.common import run_forced_device_worker
    rec = run_forced_device_worker(__file__, _WORKER_FLAG,
                                   error_row="kernel/mesh_sync/ERROR",
                                   print_fn=print_fn)
    if not rec:
        return {}
    for name in ("gated", "mesh_resident", "fsdp_grouped"):
        r = rec[name]
        print_fn(csv_row(
            f"kernel/mesh_sync/{name}", 0.0,
            f"launches={r['pallas_launches']};"
            f"n_groups={r['n_groups']};"
            f"collectives={r['collectives']};"
            f"assembly_collectives={r['assembly_collectives']};"
            f"assembly_bytes={r['assembly_bytes']};"
            f"weight_allreduce_bytes={r['replica_allreduce_bytes']};"
            f"ici_bytes_per_sync={r['ici_bytes_per_sync']:.3e}"))
    return rec


def main(print_fn=print):
    out = {}
    N = 1 << 15 if SMOKE else 1 << 20
    I, K = 8, 4
    ring = jnp.zeros((I, N), jnp.float32)
    total = jnp.zeros((N,), jnp.float32)
    new = jnp.ones((N,), jnp.float32)

    ref = jax.jit(lambda r, t, n: kref.wa_window_update_ref(
        r, t, n, 3, 1.0, 1.0 / I))
    us = _time(ref, ring, total, new)
    naive_bytes = (6 * N + 3 * N) * 4
    fused_bytes = (3 * N + 3 * N) * 4
    out["wa_window_update"] = {"us": us, "bytes_naive": naive_bytes,
                               "bytes_fused": fused_bytes}
    print_fn(csv_row("kernel/wa_window_update", us,
                     f"bytes_naive={naive_bytes};bytes_fused={fused_bytes};"
                     f"traffic_cut={1 - fused_bytes / naive_bytes:.2f}"))

    stacked = jnp.ones((K, N), jnp.float32)
    ref2 = jax.jit(kref.online_mean_ref)
    us = _time(ref2, stacked)
    out["online_mean"] = {"us": us, "bytes": (K * N + N) * 4}
    print_fn(csv_row("kernel/online_mean", us,
                     f"bytes={(K * N + N) * 4}"))

    # fused sync: (K+2) reads + 3 writes vs two kernels' (K+3) + 4
    ref3 = jax.jit(lambda s, r, t: kref.wa_sync_fused_ref(
        s, r, t, 3, 1.0, 1.0 / I))
    us = _time(ref3, stacked, ring, total)
    sync_fused_bytes = ((K + 2) * N + 3 * N) * 4
    sync_split_bytes = ((K + 3) * N + 4 * N) * 4
    out["wa_sync_fused"] = {"us": us, "bytes_fused": sync_fused_bytes,
                            "bytes_two_kernel": sync_split_bytes}
    print_fn(csv_row("kernel/wa_sync_fused", us,
                     f"bytes_fused={sync_fused_bytes};"
                     f"bytes_two_kernel={sync_split_bytes};"
                     f"traffic_cut={1 - sync_fused_bytes / sync_split_bytes:.2f}"))

    out["packed_vs_per_leaf"] = packed_vs_per_leaf(print_fn)
    out["mesh_sync_gated_vs_resident"] = gated_vs_mesh_resident(print_fn)
    out.update(attention_suite(print_fn))
    return out


def attention_suite(print_fn=print):
    """Attention fwd + bwd + train-step blocks.

    Forward wall times compare the XLA implementations (naive O(S^2) ref
    vs blockwise flash_jnp) at full size; the Pallas pipeline runs in
    interpret mode on CPU, so its wall time is measured at a CAPPED size
    (a tracer-speed number, not a kernel speed — TPU is the target) and
    its real contract here is STRUCTURAL: exactly 1 forward launch and 2
    recompute-backward sweep launches (dq k-innermost; dk/dv q-innermost)
    under ``jax.grad``, guarded by thresholds.json. The train-step block
    times one jitted value_and_grad+SGD step of the smoke model with
    flash_pallas vs flash_jnp attention and pins the same 3-launch
    budget through the model's layer scan (structural: the scan body
    traces once, so the jaxpr count is depth-independent)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import flash_attention_jnp

    out = {}
    B, S, H, D = (2, 256, 4, 64) if SMOKE else (2, 1024, 4, 64)
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    naive = jax.jit(lambda q, k, v: kref.attention_ref(q, k, v))
    us_naive = _time(naive, q, k, v, iters=5)
    flash = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v))
    us_flash = _time(flash, q, k, v, iters=5)
    out["attention_naive_ref"] = {"us": us_naive}
    out["attention_flash_jnp"] = {"us": us_flash}
    print_fn(csv_row("kernel/attention_naive_ref", us_naive,
                     f"S={S};mem=O(S^2)"))
    print_fn(csv_row("kernel/attention_flash_jnp", us_flash,
                     f"S={S};mem=O(S*block)"))

    # --- backward: jax.grad wall times at full size (XLA refs) ---------
    w = jax.random.normal(ks[3], (B, S, H, D), jnp.float32)
    g_naive = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(kref.attention_ref(q, k, v) * w),
        (0, 1, 2)))
    us_naive_bwd = _time(g_naive, q, k, v, iters=5)
    g_flash = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(flash_attention_jnp(q, k, v) * w),
        (0, 1, 2)))
    us_flash_bwd = _time(g_flash, q, k, v, iters=5)

    # Pallas custom-vjp leg, capped (interpret mode pays tracer overhead
    # per block — the structural launch counts are the portable claim)
    Sp, Hkv = 128, 2
    kp = jax.random.split(jax.random.key(1), 4)
    qs = jax.random.normal(kp[0], (B, Sp, H, D), jnp.float32)
    ks_ = jax.random.normal(kp[1], (B, Sp, Hkv, D), jnp.float32)
    vs = jax.random.normal(kp[2], (B, Sp, Hkv, D), jnp.float32)
    ws = jax.random.normal(kp[3], (B, Sp, H, D), jnp.float32)

    def fwd(q, k, v):
        return flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                      interpret=True)

    g_pallas = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(fwd(q, k, v) * ws), (0, 1, 2)))
    us_pallas_bwd = _time(g_pallas, qs, ks_, vs, iters=3)

    fwd_launches = count_pallas_calls(
        jax.make_jaxpr(fwd)(qs, ks_, vs))
    fwd_bwd_launches = count_pallas_calls(jax.make_jaxpr(jax.grad(
        lambda q, k, v: jnp.sum(fwd(q, k, v) * ws), (0, 1, 2)))(
            qs, ks_, vs))
    out["attention_bwd"] = {
        "us_naive_ref": us_naive_bwd,
        "us_flash_jnp": us_flash_bwd,
        "us_pallas_interp": us_pallas_bwd,
        "S": S, "S_pallas_interp": Sp,
        "fwd_launches": fwd_launches,
        "bwd_launches": fwd_bwd_launches - fwd_launches,
        "fwd_bwd_launches": fwd_bwd_launches,
    }
    print_fn(csv_row(
        "kernel/attention_bwd", us_flash_bwd,
        f"S={S};naive_us={us_naive_bwd:.0f};"
        f"pallas_interp_us@S{Sp}={us_pallas_bwd:.0f};"
        f"fwd_launches={fwd_launches};"
        f"bwd_launches={fwd_bwd_launches - fwd_launches}"))

    # --- train step: the smoke model end-to-end, both attention paths --
    from repro.configs import get_smoke_config
    from repro.launch.specs import input_specs
    from repro.models.registry import build_model
    from repro.models.types import InputShape

    cfg = get_smoke_config("granite-3-2b")
    shape = InputShape("tiny", seq_len=16, global_batch=2, kind="train")
    specs, _ = input_specs(cfg, shape)
    batch = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
    rec = {}
    for impl in ("flash_jnp", "flash_pallas"):
        lm = build_model(cfg.with_(attn_impl=impl))
        params = lm.init(jax.random.key(0))

        def step(p, b, lm=lm):
            (loss, _), grads = jax.value_and_grad(
                lm.loss, has_aux=True)(p, b)
            return jax.tree.map(lambda x, g: x - 0.01 * g, p, grads), loss

        rec[f"{impl}_us"] = _time(jax.jit(step), params, batch, iters=3)
        if impl == "flash_pallas":
            rec["flash_pallas_structural_launches"] = count_pallas_calls(
                jax.make_jaxpr(step)(params, batch))
            rec["n_layers"] = lm.cfg.n_layers
    out["attention_train_step"] = rec
    print_fn(csv_row(
        "kernel/attention_train_step", rec["flash_pallas_us"],
        f"flash_jnp_us={rec['flash_jnp_us']:.0f};"
        f"structural_launches={rec['flash_pallas_structural_launches']};"
        f"n_layers={rec['n_layers']}"))
    return out


if __name__ == "__main__":
    if _WORKER_FLAG in sys.argv:
        _mesh_sync_worker()
    elif "--attn-only" in sys.argv:
        # print-only lane (`make bench-attn`): benchmarks.run owns
        # BENCH_kernels.json merging; a partial dict would drop the other
        # kernel blocks, so this path never writes JSON
        attention_suite()
    else:
        main()
