"""Kernel micro-benchmarks.

Wall-times are the jit'd XLA *reference* implementations on CPU (the
Pallas kernels run in interpret mode here — TPU is the target, so their
value is the HBM-traffic model, reported as derived columns):

  fused wa_window_update : 3 reads + 3 writes vs naive 6 reads + 3 writes
  online_mean            : K reads + 1 write (fused cast)
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from benchmarks.common import csv_row


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6


def main(print_fn=print):
    N = 1 << 20
    I, K = 8, 4
    ring = jnp.zeros((I, N), jnp.float32)
    total = jnp.zeros((N,), jnp.float32)
    new = jnp.ones((N,), jnp.float32)

    ref = jax.jit(lambda r, t, n: kref.wa_window_update_ref(
        r, t, n, 3, 1.0, 1.0 / I))
    us = _time(ref, ring, total, new)
    naive_bytes = (6 * N + 3 * N) * 4
    fused_bytes = (3 * N + 3 * N) * 4
    print_fn(csv_row("kernel/wa_window_update", us,
                     f"bytes_naive={naive_bytes};bytes_fused={fused_bytes};"
                     f"traffic_cut={1 - fused_bytes / naive_bytes:.2f}"))

    stacked = jnp.ones((K, N), jnp.float32)
    ref2 = jax.jit(kref.online_mean_ref)
    us = _time(ref2, stacked)
    print_fn(csv_row("kernel/online_mean", us,
                     f"bytes={(K * N + N) * 4}"))

    B, S, H, D = 2, 1024, 4, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    naive = jax.jit(lambda q, k, v: kref.attention_ref(q, k, v))
    us_naive = _time(naive, q, k, v, iters=5)
    from repro.models.attention import flash_attention_jnp
    flash = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v))
    us_flash = _time(flash, q, k, v, iters=5)
    print_fn(csv_row("kernel/attention_naive_ref", us_naive,
                     f"S={S};mem=O(S^2)"))
    print_fn(csv_row("kernel/attention_flash_jnp", us_flash,
                     f"S={S};mem=O(S*block)"))
    return {}


if __name__ == "__main__":
    main()
