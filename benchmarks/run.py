"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and tees per-table JSON into
experiments/bench/). The kernel suite's structured result (per-benchmark
µs + derived bytes/launches/padding) is additionally written to
``BENCH_kernels.json`` at the repo root so the perf trajectory is tracked
across PRs, not just printed.

  PYTHONPATH=src python -m benchmarks.run [--only table2,roofline]
"""
import argparse
import json
import os
import sys
import time

BENCHES = [
    ("table2", "benchmarks.table2_methods"),
    ("table3", "benchmarks.table3_ablation"),
    ("table4", "benchmarks.table4_k_models"),
    ("fig13", "benchmarks.fig13_window"),
    ("fig2", "benchmarks.fig2_lr_sensitivity"),
    ("fig7", "benchmarks.fig7_convergence"),
    ("fig9", "benchmarks.fig9_interpolation"),
    ("comm", "benchmarks.comm_amortization"),
    ("mesh_comm", "benchmarks.mesh_comm"),
    ("kernels", "benchmarks.kernel_bench"),
    ("sync_tree", "benchmarks.sync_tree"),
    ("comms", "benchmarks.comms_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("roofline", "benchmarks.roofline"),
]

# Benchmarks whose structured result is persisted into BENCH_kernels.json
# at the repo root (cross-PR perf trajectory). "kernels" merges its
# record at the top level (historical layout); "sync_tree" and "serve"
# append under their own keys — existing keys from other benchmarks
# survive.
_BENCH_JSON_KEY = {"kernels": None, "sync_tree": "sync/tree",
                   "comms": "sync/comms", "serve": "serve"}


def _merge_bench_json(name: str, result: dict) -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_kernels.json")
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    key = _BENCH_JSON_KEY[name]
    if key is None:
        # kernels owns the top level: drop its stale keys (a renamed or
        # removed benchmark must not linger as a "current" measurement),
        # keeping only the blocks other benchmarks own
        keep = {k for k in _BENCH_JSON_KEY.values() if k is not None}
        data = {k: v for k, v in data.items() if k in keep}
        data.update(result)
    else:
        data[key] = result
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs("experiments/bench", exist_ok=True)
    print("name,us_per_call,derived")
    rows = []

    def sink(line):
        print(line, flush=True)
        rows.append(line)

    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        mod = __import__(module, fromlist=["main"])
        try:
            result = mod.main(print_fn=sink)
        except Exception as e:  # noqa: BLE001 — report and continue
            result = None
            sink(f"{name}/ERROR,0,{type(e).__name__}: {e}")
        sink(f"{name}/wall_s,{(time.time()-t0)*1e6:.0f},done")
        if name in _BENCH_JSON_KEY and isinstance(result, dict) and result:
            _merge_bench_json(name, result)
    with open("experiments/bench/rows.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(rows) + "\n")


if __name__ == '__main__':
    main()
