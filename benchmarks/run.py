"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and tees per-table JSON into
experiments/bench/). The kernel suite's structured result (per-benchmark
µs + derived bytes/launches/padding) is additionally written to
``BENCH_kernels.json`` at the repo root so the perf trajectory is tracked
across PRs, not just printed.

  PYTHONPATH=src python -m benchmarks.run [--only table2,roofline]
"""
import argparse
import json
import os
import sys
import time

BENCHES = [
    ("table2", "benchmarks.table2_methods"),
    ("table3", "benchmarks.table3_ablation"),
    ("table4", "benchmarks.table4_k_models"),
    ("fig13", "benchmarks.fig13_window"),
    ("fig2", "benchmarks.fig2_lr_sensitivity"),
    ("fig7", "benchmarks.fig7_convergence"),
    ("fig9", "benchmarks.fig9_interpolation"),
    ("comm", "benchmarks.comm_amortization"),
    ("mesh_comm", "benchmarks.mesh_comm"),
    ("kernels", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs("experiments/bench", exist_ok=True)
    print("name,us_per_call,derived")
    rows = []

    def sink(line):
        print(line, flush=True)
        rows.append(line)

    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        mod = __import__(module, fromlist=["main"])
        try:
            result = mod.main(print_fn=sink)
        except Exception as e:  # noqa: BLE001 — report and continue
            result = None
            sink(f"{name}/ERROR,0,{type(e).__name__}: {e}")
        sink(f"{name}/wall_s,{(time.time()-t0)*1e6:.0f},done")
        if name == "kernels" and isinstance(result, dict) and result:
            root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            with open(os.path.join(root, "BENCH_kernels.json"), "w") as f:
                json.dump(result, f, indent=2, sort_keys=True)
    with open("experiments/bench/rows.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(rows) + "\n")


if __name__ == '__main__':
    main()
