"""Compressed WA-state + cross-pod comms numbers (PR 10), measured from
real lowered HLO and real sync outputs on the pod-carved (2,2,2) test
mesh (pod=2, replica=2, model=2 → K=4 as 2 pods × 2 members).

For each precision token (f32 / bf16 / fp8) the worker builds the
two-level outer sync via ``SyncPlan(wa_dtype=tok, comms_dtype=tok)`` and
records:

- **ring HBM**: bytes of the (I, P) window ring in the token's storage
  dtype (+ the fp8 per-ALIGN-block f32 scales), and the ratio vs f32 —
  the WA-state HBM reduction. The ratio is stated on the ring (+scales),
  the (I, P) term that dominates WA state as the window I grows; the f32
  running total and Kahan compensation are (P,) and amortize away.
- **cross-pod payload**: modeled per-device ICI bytes of the collectives
  crossing the pod axis in the compiled HLO (same traffic model as
  ``benchmarks.sync_tree``), and the ratio vs f32. Both compressed
  payloads cross the wire as same-width integer bit-views (bf16→u16
  gather, ~2×; fp8→u8 gather + f32 per-block scales, ~4×) so XLA's
  float-normalization pass cannot widen them back — these are REAL
  compiled wire bytes, not a semantic claim.
- **bounded-ULP parity**: the compressed W̿ against the f32 leg's, in
  relative ULPs of the compressed dtype at the buffer's working scale
  (``repro.common.quant.rel_ulp_error``) — guarded by the per-dtype
  budgets in ``benchmarks/thresholds.json``'s ``ulp_budgets`` section.
  The f32 leg must report exactly 0.0 (bit-identical — the repo-wide
  f32-default guarantee).

``make bench-comms`` runs this module alone; ``benchmarks.run`` merges
the record into BENCH_kernels.json under ``sync/comms``. The
device-hungry part runs in a subprocess so the forced 8-device host
platform never leaks into the benchmark process.
"""
import json
import sys

from benchmarks.common import csv_row

_WORKER_FLAG = "--comms-worker"

TOKENS = ("f32", "bf16", "fp8")


def comms_record() -> dict:
    """Build + compile + RUN the two-level outer sync at each precision
    and extract HBM/payload/parity numbers. Needs ≥8 forced host
    devices."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.collectives import collective_stats
    from repro.common.compat import use_mesh
    from repro.common.quant import rel_ulp_error, wa_dtype
    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch.hlo import sync_collective_audit
    from repro.launch.mesh import make_tree_test_mesh
    from repro.launch.steps import (SyncPlan, TwoLevel, build_hwa_bundles,
                                    window_state_args)
    from repro.models.registry import build_model
    from repro.sharding.rules import make_tp_rules

    mesh = make_tree_test_mesh()
    rules = make_tp_rules(mesh, replica_axis=("pod", "replica"))
    lm = build_model(get_smoke_config("granite-3-2b"))
    hwa = HWAConfig(n_replicas=4, window=3, use_kernels=True, outer_every=2)
    topo = TwoLevel("replica", "pod", outer_every=2)

    params = lm.init(jax.random.key(0))
    div = jax.tree.map(
        lambda x: np.asarray(                    # host copy: sync donates
            x[None] + 0.1 * jax.random.normal(jax.random.key(7),
                                              (4,) + x.shape)), params)

    rec = {"mesh": {k: int(v) for k, v in mesh.shape.items()},
           "window": hwa.window}
    for tok in TOKENS:
        plan = SyncPlan(hwa=hwa, topology=topo, wa_dtype=tok,
                        comms_dtype=tok)
        sync = build_hwa_bundles(lm, rules, plan).sync
        spec = sync.pack_spec
        compiled = sync.lower(mesh).compile()
        audit = sync_collective_audit(compiled.as_text(), mesh,
                                      "replica", "pod")
        pod_text = "\n".join(line for _, line in audit["outer"])
        pod_bytes = collective_stats(pod_text).traffic_bytes

        itemsize = np.dtype(wa_dtype(tok)).itemsize
        ring_bytes = hwa.window * spec.padded * itemsize
        scale_bytes = (hwa.window * (spec.padded // spec.align) * 4
                       if tok == "fp8" else 0)

        win = window_state_args(sync)
        n_buf = len(win) - 3
        with use_mesh(mesh):
            out = compiled(jax.tree.map(jnp.asarray, div), *win)
        wa = jax.tree.map(lambda x: np.asarray(x), out[3 + n_buf])

        rec[tok] = {
            "ring_bytes": ring_bytes + scale_bytes,
            "scale_bytes": scale_bytes,
            "outer_payload_bytes": pod_bytes,
            "outer_collectives": len(audit["outer"]),
        }
        if tok == "f32":
            rec[tok]["wa_rel_ulp_err"] = 0.0     # oracle leg
            wa_f32 = wa
        else:
            rec[tok]["ring_hbm_ratio"] = (rec["f32"]["ring_bytes"]
                                          / rec[tok]["ring_bytes"])
            rec[tok]["outer_payload_ratio"] = (
                rec["f32"]["outer_payload_bytes"] / pod_bytes
                if pod_bytes else 0.0)
            rec[tok]["wa_rel_ulp_err"] = max(
                rel_ulp_error(r, g, tok)
                for r, g in zip(jax.tree.leaves(wa_f32),
                                jax.tree.leaves(wa)))
    return rec


def _worker():
    print(json.dumps(comms_record()))


def main(print_fn=print):
    from benchmarks.common import run_forced_device_worker
    rec = run_forced_device_worker(__file__, _WORKER_FLAG,
                                   error_row="sync/comms/ERROR",
                                   print_fn=print_fn)
    if not rec:
        return {}
    for tok in TOKENS:
        r = rec[tok]
        print_fn(csv_row(
            f"sync/comms/{tok}", 0.0,
            f"ring_bytes={r['ring_bytes']:.3e};"
            f"outer_payload_bytes={r['outer_payload_bytes']:.3e};"
            f"ring_hbm_ratio={r.get('ring_hbm_ratio', 1.0):.2f};"
            f"outer_payload_ratio={r.get('outer_payload_ratio', 1.0):.2f};"
            f"wa_rel_ulp_err={r['wa_rel_ulp_err']:.3f}"))
    return rec


if __name__ == "__main__":
    if _WORKER_FLAG in sys.argv:
        _worker()
    else:
        main()
