"""Paper Fig. 13 — slide-window length I sweep (+ streaming variant)."""
from benchmarks.common import csv_row, run_method


def main(print_fn=print):
    rows = {}
    for window in (1, 2, 4, 8):
        out = run_method("hwa", window=window)
        rows[window] = out
        print_fn(csv_row(
            f"fig13/I={window}", out["us_per_step"],
            f"best_acc={out['best']['test_acc']:.4f};"
            f"best_loss={out['best']['test_loss']:.4f}"))
    return rows


if __name__ == "__main__":
    main()
