"""Paper Fig. 9 — loss/accuracy along the line between the averaged model
(x=0) and an individual inner model (x=1).

Claims: (a) no sharp barrier (same basin); (b) the averaged model has
LOWER test loss despite HIGHER (or equal) train loss than the individual
model — it sits on the flat side of the asymmetric valley.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, run_method
from repro.common.pytree import tree_lerp
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from benchmarks.common import TINY, SEQ, N_TRAIN, BATCH


def main(print_fn=print):
    out = run_method("hwa", eval_views=True, steps=256)   # the W̿ optimum (fig7)
    lm = build_model(TINY)
    ds = make_markov_lm_dataset(vocab=TINY.vocab_size, seq_len=SEQ,
                                n_train=N_TRAIN, n_test=128, seed=0)

    # W̿ (averaged) and an individual inner model from the final state:
    # re-run the tail is avoided — run() returns final W̿; rebuild an inner
    # model by one extra epoch of SGD from it (a point on the basin rim).
    from repro.core import HWAConfig, hwa_init, hwa_inner_step
    from repro.optim import sgd
    wa = out["params"]
    hcfg = HWAConfig(n_replicas=1, sync_period=32, window=1)
    opt = sgd(momentum=0.9, weight_decay=5e-4)
    state = hwa_init(hcfg, wa, opt)
    pipe = DataPipeline(ds, batch_size=BATCH, n_replicas=1, seed=7)

    def loss_fn(params, batch):
        b = {"tokens": batch[0], "targets": batch[1]}
        return lm.loss(params, b)

    import jax as _jax
    step_fn = _jax.jit(lambda st, i: hwa_inner_step(
        hcfg, st, _jax.tree.map(lambda x: x[None], pipe.replica_batch(0, i)),
        loss_fn, opt, 0.3))
    for i in range(32):
        state, _ = step_fn(state, i)
    individual = _jax.tree.map(lambda x: x[0], state.inner)

    @_jax.jit
    def losses_at(t):
        p = tree_lerp(wa, individual, t)
        train_l, _ = lm.loss(p, {"tokens": ds.train_inputs[:128],
                                 "targets": ds.train_targets[:128]})
        test_l, _ = lm.loss(p, {"tokens": ds.test_inputs,
                                "targets": ds.test_targets})
        return train_l, test_l

    rows = []
    for t in [0.0, 0.25, 0.5, 0.75, 1.0]:
        tr, te = losses_at(t)
        rows.append((t, float(tr), float(te)))
        print_fn(csv_row(f"fig9/x={t}", 0.0,
                         f"train_loss={float(tr):.4f};"
                         f"test_loss={float(te):.4f}"))
    barrier = max(r[2] for r in rows) - max(rows[0][2], rows[-1][2])
    print_fn(csv_row("fig9/no_sharp_barrier", 0.0,
                     f"mid_bump={barrier:.4f}"))
    print_fn(csv_row(
        "fig9/avg_better_test", 0.0,
        f"avg_test={rows[0][2]:.4f};indiv_test={rows[-1][2]:.4f};"
        f"avg_wins={rows[0][2] < rows[-1][2]}"))
    return rows


if __name__ == "__main__":
    main()
