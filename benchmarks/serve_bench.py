"""Serving-tier benchmark: continuous batching vs static batching at
ragged occupancy.

The workload is the shape continuous batching exists for: a few long
generations pinning the batch while many short ones come and go (here
4×48-token + 12×2-token requests). The baseline is STATIC batching at
the same concurrency budget (``MAX_BATCH`` slots — the same KV memory
both engines get): requests are grouped FIFO (generation lengths are
not known up front — they are EOS-dependent in real serving) and every
group runs to its longest member's step count, ``Σ_groups max(n_new) ×
MAX_BATCH`` token-slots for ``Σ n_new`` useful tokens. The paged engine
admits a new request into a slot the moment one finishes, so its
token-slot count tracks the useful work.

Decode on CPU (as on accelerators) is weight-streaming bound — a step's
cost is nearly independent of batch width — so the smoke config is
widened (d_model 256, 4 layers) until device work dominates the host
scheduling loop; the tiny test width would measure dispatch overhead.

Recorded (merged into BENCH_kernels.json under ``"serve"``):

- ``static.tok_s`` / ``paged.tok_s``: useful tokens per wall-second
  (compile excluded — both engines measured on their second run) and
  ``speedup_tok_s``. Wall numbers are machine-dependent: recorded for
  the trajectory, NOT bounded by thresholds.json.
- ``work_ratio``: static token-slots / paged token-slots — the
  STRUCTURAL occupancy win, machine-independent; thresholds pin it ≥ 2.
- ``paged.decode_step_traces``: must be exactly 1 — admissions,
  evictions and ragged lengths never retrace the fixed-shape step.
- ``parity_mismatches``: must be 0 — the measured runs are also a
  bit-parity check (greedy tokens equal per request).
"""
import time

import numpy as np

from benchmarks.common import csv_row

ARCH = "granite-3-2b"
WIDTH = dict(d_model=256, d_ff=1024, n_layers=4, n_heads=8, n_kv_heads=4)
PROMPT_LEN = 12
N_NEW = [48, 2, 2, 2] * 4          # ragged: 4 long pins, 12 short riders
MAX_BATCH = 4                      # concurrency budget for BOTH engines
MAX_SEQ = 64
PAGE_SIZE = 4


def serve_record() -> dict:
    import jax

    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.serve.engine import DecodeEngine, PagedDecodeEngine
    from repro.serve.scheduler import ContinuousScheduler, Request

    cfg = get_smoke_config(ARCH).with_(**WIDTH)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size,
                          size=(len(N_NEW), PROMPT_LEN)).astype(np.int32)
    useful = int(sum(N_NEW))
    max_new = max(N_NEW)
    groups = [list(range(g, g + MAX_BATCH))
              for g in range(0, len(N_NEW), MAX_BATCH)]

    # static batching: FIFO groups of MAX_BATCH, each to its longest rider
    ref = DecodeEngine(lm=lm, params=params, max_seq_len=MAX_SEQ)

    def run_static():
        outs = {}
        for grp in groups:
            nmax = max(N_NEW[i] for i in grp)
            out = jax.block_until_ready(ref.generate(
                {"tokens": jax.numpy.asarray(prompts[grp])}, nmax))
            for j, i in enumerate(grp):
                outs[i] = np.asarray(out[j, :N_NEW[i]])
        return outs
    run_static()                                 # compile
    t0 = time.time()
    want = run_static()
    t_static = time.time() - t0
    slots_static = sum(max(N_NEW[i] for i in g) for g in groups) * MAX_BATCH

    # paged continuous batching: same budget, admit-on-evict
    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=MAX_BATCH,
                            max_seq_len=MAX_SEQ, max_new=max_new,
                            page_size=PAGE_SIZE, prefill_chunk=16)
    reqs = [Request(rid=i, tokens=prompts[i], n_new=n)
            for i, n in enumerate(N_NEW)]
    n_steps = 0
    orig_step = eng.step

    def counted_step(ctrl):
        nonlocal n_steps
        n_steps += 1
        return orig_step(ctrl)

    eng.step = counted_step

    def run_paged():
        return ContinuousScheduler(eng).run(reqs, max_steps=5000)
    run_paged()                                  # compile
    n_steps = 0
    t0 = time.time()
    outs = run_paged()
    t_paged = time.time() - t0
    slots_paged = n_steps * MAX_BATCH

    mismatches = sum(int(not np.array_equal(outs[i], want[i]))
                     for i in range(len(N_NEW)))

    return {
        "arch": ARCH,
        "width": dict(WIDTH),
        "n_requests": len(N_NEW),
        "useful_tokens": useful,
        "max_new": max_new,
        "static": {"wall_s": t_static, "tok_s": useful / t_static,
                   "token_slots": slots_static},
        "paged": {"wall_s": t_paged, "tok_s": useful / t_paged,
                  "token_slots": slots_paged, "steps": n_steps,
                  "max_batch": MAX_BATCH,
                  "decode_step_traces": eng.step_traces},
        "speedup_tok_s": t_static / t_paged,
        "work_ratio": slots_static / slots_paged,
        "parity_mismatches": mismatches,
    }


def main(print_fn=print):
    rec = serve_record()
    for name in ("static", "paged"):
        r = rec[name]
        print_fn(csv_row(f"serve/{name}", r["wall_s"] * 1e6,
                         f"tok_s={r['tok_s']:.1f};"
                         f"token_slots={r['token_slots']}"))
    print_fn(csv_row(
        "serve/summary", 0.0,
        f"speedup_tok_s={rec['speedup_tok_s']:.2f};"
        f"work_ratio={rec['work_ratio']:.2f};"
        f"decode_step_traces={rec['paged']['decode_step_traces']};"
        f"parity_mismatches={rec['parity_mismatches']}"))
    return rec


if __name__ == "__main__":
    main()
