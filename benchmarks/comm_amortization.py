"""HWA's communication-reduction claim (paper §I), quantified from the
dry-run artifacts: inter-replica traffic of HWA (one weight all-reduce
per H steps) vs per-step gradient data parallelism, as a function of H.
"""
import glob
import json
import os

from repro.launch.hlo import ICI_BW

from benchmarks.common import csv_row


def main(print_fn=print, dryrun_dir="experiments/dryrun"):
    rows = {}
    sync_files = glob.glob(os.path.join(dryrun_dir, "*hwa_sync*.json"))
    train_files = glob.glob(os.path.join(dryrun_dir, "*hwa_train*.json"))
    if not sync_files:
        print_fn(csv_row("comm/skipped", 0.0,
                         "no hwa_sync dry-run artifacts yet"))
        return rows
    for sf in sorted(sync_files):
        rec = json.load(open(sf))
        arch = rec["arch"]
        sync_bytes = rec["collectives"]["traffic_bytes_per_device"]
        # matching inner-step record (no cross-replica traffic expected)
        inner = None
        for tf in train_files:
            r2 = json.load(open(tf))
            if r2["arch"] == arch and r2["mesh"] == rec["mesh"]:
                inner = r2
        inner_bytes = (inner["collectives"]["traffic_bytes_per_device"]
                       if inner else 0.0)
        # data-parallel gradient sync each step ≈ the same all-reduce the
        # HWA sync performs once per H steps
        for H in (1, 64, 391, 1024):
            per_step = inner_bytes + sync_bytes / H
            print_fn(csv_row(
                f"comm/{arch}/{rec['mesh']}/H={H}",
                per_step / ICI_BW * 1e6,
                f"bytes_per_step={per_step:.3e};"
                f"sync_bytes={sync_bytes:.3e};inner={inner_bytes:.3e}"))
        rows[arch] = {"sync": sync_bytes, "inner": inner_bytes}
    return rows


if __name__ == "__main__":
    main()
