"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh): the three terms in seconds, dominant bound,
model FLOPs, useful-compute ratio, per-device memory fit.
"""
import glob
import json
import os

from benchmarks.common import csv_row


def load_records(dryrun_dir="experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(path)))
    return recs


def main(print_fn=print, dryrun_dir="experiments/dryrun"):
    recs = load_records(dryrun_dir)
    if not recs:
        print_fn(csv_row("roofline/skipped", 0.0, "run dryrun first"))
        return []
    for r in recs:
        t = r["roofline"]
        print_fn(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['step']}",
            t["bound_s"] * 1e6,
            f"compute_s={t['compute_s']:.3f};memory_s={t['memory_s']:.3f};"
            f"collective_s={t['collective_s']:.3f};dom={t['dominant']};"
            f"peak_GB={r['memory']['peak_bytes']/1e9:.2f};"
            f"fits={r['memory']['fits_16GB']};"
            f"useful={r['useful_compute_ratio']:.3f}"))
    n_fit = sum(r["memory"]["fits_16GB"] for r in recs)
    print_fn(csv_row("roofline/fit_summary", 0.0,
                     f"{n_fit}/{len(recs)} combos fit 16GB"))
    return recs


if __name__ == "__main__":
    main()
