"""Paper Table II — methods comparison (Baseline/CA/SWA/EMA/Lookahead/SAM/
online-WA/HWA) at CPU proxy scale. Claim: HWA best test metric."""
from benchmarks.common import csv_row, run_method

METHODS = ["base", "ca", "swa", "ema", "lookahead", "sam", "online", "hwa"]


SEEDS = (0, 1, 2)


def main(print_fn=print):
    rows = {}
    for m in METHODS:
        outs = [run_method(m, seed=s) for s in SEEDS]
        acc = sum(o["best"]["test_acc"] for o in outs) / len(outs)
        loss = sum(o["best"]["test_loss"] for o in outs) / len(outs)
        us = sum(o["us_per_step"] for o in outs) / len(outs)
        rows[m] = {"acc": acc, "loss": loss}
        print_fn(csv_row(
            f"table2/{m}", us,
            f"best_acc_mean{len(SEEDS)}seeds={acc:.4f};"
            f"best_loss_mean={loss:.4f}"))
    best = max(rows, key=lambda m: rows[m]["acc"])
    print_fn(csv_row("table2/winner", 0.0, f"method={best}"))
    return rows


if __name__ == "__main__":
    main()
