"""Shared benchmark harness.

CPU-feasible proxy for the paper's CIFAR protocol: a 2-layer transformer
LM on a synthetic 2nd-order-learnable Markov task with a real train/test
generalization gap (DESIGN.md §8 deviation 1). Every method uses the same
budget, data and init seed; HWA uses H = one epoch (paper default) and
I = WINDOW.
"""
from __future__ import annotations

import time

from repro.core import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.models.types import ModelConfig
from repro.train import TrainConfig, Trainer, lm_task

VOCAB = 64
SEQ = 48
STEPS = 512
BATCH = 8
N_TRAIN = 256          # 32 steps/epoch -> 16 epochs/sync cycles
WINDOW = 4
BASE_LR = 0.5

TINY = ModelConfig(name="bench-lm", family="dense", n_layers=2, d_model=48,
                   n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=VOCAB,
                   attn_impl="naive", remat="none", dtype="float32")


def run_method(method: str, *, k: int = 2, window: int = WINDOW,
               sync_period: int = 0, steps: int = STEPS, seed: int = 0,
               base_lr: float = BASE_LR, swa_lr: float = 0.1,
               eval_views: bool = False, model: ModelConfig = TINY):
    lm = build_model(model)
    ds = make_markov_lm_dataset(vocab=model.vocab_size, seq_len=SEQ,
                                n_train=N_TRAIN, n_test=128, seed=0)
    kk = k if method in ("hwa", "online", "pmsgd") else 1
    pipe = DataPipeline(ds, batch_size=BATCH, n_replicas=kk, seed=seed)
    tc = TrainConfig(method=method, total_steps=steps, batch_size=BATCH,
                     base_lr=base_lr, seed=seed, swa_lr=swa_lr,
                     swa_start_frac=0.6,
                     eval_every=max(N_TRAIN // BATCH, 1),
                     hwa=HWAConfig(n_replicas=kk, sync_period=sync_period,
                                   window=window))
    t0 = time.time()
    out = Trainer(lm_task(lm, pipe), tc).run(eval_views=eval_views)
    out["seconds"] = time.time() - t0
    out["us_per_step"] = out["seconds"] / steps * 1e6
    return out


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def run_forced_device_worker(worker_file: str, flag: str, *,
                             error_row: str, print_fn=print,
                             n_devices: int = 8, timeout: int = 600):
    """Re-exec ``worker_file`` with ``flag`` under N forced host devices
    and return its last-stdout-line JSON dict ({} on failure).

    Mesh benchmarks must run the device-hungry part in a subprocess so
    the forced host platform never leaks into the benchmark process;
    this is the shared driver (benchmarks/mesh_comm.py,
    benchmarks/kernel_bench.py).
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root + \
        os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(worker_file), flag],
        capture_output=True, text=True, env=env, timeout=timeout, cwd=root)
    if proc.returncode != 0:
        print_fn(csv_row(error_row, 0.0,
                         (proc.stderr or proc.stdout)[-160:].replace(
                             "\n", " ").replace(",", ";")))
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])
