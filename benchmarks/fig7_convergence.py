"""Paper Figs. 3/7/14 — convergence of inner vs outer vs HWA weights.

Claim: test loss of W̿ (HWA weights) ≤ W̄ (outer) ≤ W^k (inner) along
training — both averaging levels speed up convergence.
"""
from benchmarks.common import csv_row, run_method


def main(print_fn=print):
    out = run_method("hwa", eval_views=True)
    wins_outer = wins_inner = n = 0
    for rec in out["history"]:
        if "outer_loss" in rec:
            n += 1
            wins_outer += rec["test_loss"] <= rec["outer_loss"] + 1e-6
            wins_inner += rec["outer_loss"] <= rec["inner_loss"] + 1e-6
    for rec in out["history"]:
        if "outer_loss" in rec:
            print_fn(csv_row(
                f"fig7/step={rec['step']}", 0.0,
                f"inner={rec['inner_loss']:.4f};outer={rec['outer_loss']:.4f};"
                f"hwa={rec['test_loss']:.4f}"))
    print_fn(csv_row("fig7/hwa<=outer_fraction", out["us_per_step"],
                     f"{wins_outer}/{n}"))
    print_fn(csv_row("fig7/outer<=inner_fraction", 0.0, f"{wins_inner}/{n}"))
    return out


if __name__ == "__main__":
    main()
