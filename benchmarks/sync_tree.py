"""Flat vs two-level sync-tree traffic, measured from real lowered HLO on
the (2,2,2) pod-carved test mesh (pod=2, replica=2, model=2 → K=4 as
2 pods × 2 members).

Both topologies run on the SAME mesh so "cross-pod bytes" is a
well-defined quantity for both: the flat baseline is ``Flat(("pod",
"replica"))`` — one joint all-reduce whose groups span pods every sync —
while the tree's inner sync reduces within pods only and its outer sync
adds exactly one cross-pod all-reduce (audited per level by
``sync_collective_audit``). Per-cycle numbers model a cycle of H₂ syncs:
flat pays the pod-crossing all-reduce H₂ times, the tree once — the
H₂-fold cross-pod amortization the ISSUE/ROADMAP hierarchical-sync item
asks for, on top of the paper's H-fold.

``make bench-sync`` runs this module alone; ``benchmarks.run`` merges
the returned record into BENCH_kernels.json under the ``sync/tree`` key
(cross-PR trajectory). Runs the device-hungry part in a subprocess so
the forced 8-device host platform never leaks into the benchmark
process.
"""
import json
import sys

from benchmarks.common import csv_row

_WORKER_FLAG = "--sync-tree-worker"

OUTER_EVERY = 2          # H₂ of the measured tree bundles
CYCLE_H2 = (2, 4, 8)     # per-cycle amortization models


def tree_sync_record() -> dict:
    """Lower + compile the flat / inner / outer sync bundles on the
    pod-carved test mesh and extract per-bundle collective structure.
    Must run in a process with ≥8 (forced) host devices."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch.hlo import (collective_stats, count_pallas_calls,
                                  result_bytes, sync_collective_audit)
    from repro.launch.mesh import make_tree_test_mesh
    from repro.launch.steps import (Flat, TwoLevel,
                                    make_mesh_hwa_inner_sync_step,
                                    make_mesh_hwa_sync_step)
    from repro.models.registry import build_model
    from repro.sharding.rules import make_tp_rules

    mesh = make_tree_test_mesh()
    rules = make_tp_rules(mesh, replica_axis=("pod", "replica"))
    lm = build_model(get_smoke_config("granite-3-2b"))
    tree_cfg = HWAConfig(n_replicas=4, window=3, use_kernels=True,
                         outer_every=OUTER_EVERY)
    flat_cfg = HWAConfig(n_replicas=4, window=3, use_kernels=True)
    topo = TwoLevel("replica", "pod", outer_every=OUTER_EVERY)
    bundles = {
        "flat": make_mesh_hwa_sync_step(
            lm, rules, flat_cfg, topology=Flat(("pod", "replica"))),
        "outer": make_mesh_hwa_sync_step(lm, rules, tree_cfg, topology=topo),
        "inner": make_mesh_hwa_inner_sync_step(lm, rules, tree_cfg, topo),
    }
    rec = {"mesh": {k: int(v) for k, v in mesh.shape.items()},
           "outer_every": OUTER_EVERY}
    for name, bundle in bundles.items():
        hlo = bundle.lower(mesh).compile().as_text()
        stats = collective_stats(hlo)
        audit = sync_collective_audit(hlo, mesh, "replica", "pod")
        pod_hits = audit["outer"]        # collectives crossing pods
        pod_text = "\n".join(line for _, line in pod_hits)
        rec[name] = {
            "collectives": sum(stats.counts.values()),
            "ici_bytes_per_sync": stats.traffic_bytes,
            "pod_crossing_collectives": len(pod_hits),
            "pod_crossing_result_bytes": result_bytes(pod_hits),
            "pod_crossing_ici_bytes": collective_stats(pod_text).traffic_bytes,
            "pallas_launches": count_pallas_calls(
                jax.make_jaxpr(bundle.fn)(*bundle.abstract_args)),
            "inner_sync_ok": audit["inner_sync_ok"],
            "outer_sync_ok": audit["outer_sync_ok"],
            "mixed": len(audit["mixed"]),
        }
    # per-cycle model: a cycle = H₂ syncs; the tree runs H₂-1 inner + 1
    # outer, the flat baseline H₂ full syncs
    rec["per_cycle"] = {}
    for h2 in CYCLE_H2:
        flat_pod = h2 * rec["flat"]["pod_crossing_ici_bytes"]
        tree_pod = ((h2 - 1) * rec["inner"]["pod_crossing_ici_bytes"]
                    + rec["outer"]["pod_crossing_ici_bytes"])
        rec["per_cycle"][f"H2={h2}"] = {
            "flat_pod_bytes": flat_pod,
            "tree_pod_bytes": tree_pod,
            "flat_ici_bytes": h2 * rec["flat"]["ici_bytes_per_sync"],
            "tree_ici_bytes": ((h2 - 1) * rec["inner"]["ici_bytes_per_sync"]
                               + rec["outer"]["ici_bytes_per_sync"]),
        }
    return rec


def _worker():
    print(json.dumps(tree_sync_record()))


def main(print_fn=print):
    from benchmarks.common import run_forced_device_worker
    rec = run_forced_device_worker(__file__, _WORKER_FLAG,
                                   error_row="sync/tree/ERROR",
                                   print_fn=print_fn)
    if not rec:
        return {}
    for name in ("flat", "inner", "outer"):
        r = rec[name]
        print_fn(csv_row(
            f"sync/tree/{name}", 0.0,
            f"collectives={r['collectives']};"
            f"ici_bytes_per_sync={r['ici_bytes_per_sync']:.3e};"
            f"pod_crossing_collectives={r['pod_crossing_collectives']};"
            f"pod_crossing_ici_bytes={r['pod_crossing_ici_bytes']:.3e};"
            f"launches={r['pallas_launches']};"
            f"inner_ok={r['inner_sync_ok']};outer_ok={r['outer_sync_ok']}"))
    for h2, c in rec["per_cycle"].items():
        # no measured flat pod traffic -> nothing to cut (not a 100% win)
        cut = (1.0 - c["tree_pod_bytes"] / c["flat_pod_bytes"]
               if c["flat_pod_bytes"] else 0.0)
        print_fn(csv_row(
            f"sync/tree/cycle/{h2}", 0.0,
            f"flat_pod_bytes={c['flat_pod_bytes']:.3e};"
            f"tree_pod_bytes={c['tree_pod_bytes']:.3e};"
            f"pod_traffic_cut={cut:.2f}"))
    return rec


if __name__ == "__main__":
    if _WORKER_FLAG in sys.argv:
        _worker()
    else:
        main()
