"""Paper Table III — module ablation: CA → +online WA → +offline window."""
from benchmarks.common import csv_row, run_method


def main(print_fn=print):
    rows = {}
    for name, method, kw in [
            ("ca", "ca", {}),
            ("online_module", "online", {}),
            ("online+offline(hwa)", "hwa", {})]:
        out = run_method(method, **kw)
        rows[name] = out
        print_fn(csv_row(
            f"table3/{name}", out["us_per_step"],
            f"best_acc={out['best']['test_acc']:.4f};"
            f"best_loss={out['best']['test_loss']:.4f}"))
    return rows


if __name__ == "__main__":
    main()
