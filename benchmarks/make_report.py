"""Fill EXPERIMENTS.md sections from experiments/dryrun + experiments/bench.

  PYTHONPATH=src python -m benchmarks.make_report
"""
import glob
import json
import os
import re

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _advice(rec):
    dom = rec["roofline"]["dominant"]
    coll = rec["collectives"]["result_bytes_by_op"]
    top_coll = max(coll, key=coll.get) if coll else "none"
    if dom == "collective_s":
        return (f"reduce {top_coll} volume (overlap with compute; "
                "coarser FSDP gather granularity; bf16 collectives)")
    if dom == "memory_s":
        if rec["shape"].startswith("decode"):
            return "quantize KV cache / fewer HBM passes per token"
        return "more fusion / fewer activation round-trips (remat policy)"
    return "already compute-bound — raise MXU utilization (larger tiles)"


def dryrun_tables(dryrun_dir="experiments/dryrun"):
    recs = [json.load(open(p)) for p in
            sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))]
    base = [r for r in recs if r["step"] in ("train", "prefill", "decode")
            and not r.get("variant")]
    variants = [r for r in recs if r.get("variant")]
    hwa = [r for r in recs if r["step"].startswith("hwa")]

    n_single = sum(1 for r in base if r["mesh"] == "single")
    n_multi = sum(1 for r in base if r["mesh"] == "multi")
    fits = sum(1 for r in base if r["memory"]["fits_16GB"])
    fits_proj = sum(1 for r in base if r["memory"].get(
        "fits_16GB_tpu_projected", r["memory"]["fits_16GB"]))
    summary = (
        f"- baseline combos compiled: **{n_single} single-pod + "
        f"{n_multi} multi-pod**; HWA-variant runs: {len(hwa)}\n"
        f"- per-device memory: {fits}/{len(base)} fit 16 GB as measured on "
        f"the CPU lowering; **{fits_proj}/{len(base)}** fit after removing "
        f"the CPU f32-KV-convert artifact (note 2)\n"
        f"- compile times: "
        f"{min(r['compile_s'] for r in base):.1f}–"
        f"{max(r['compile_s'] for r in base):.1f} s per combo\n")

    # roofline table (single-pod baselines per assignment; multi-pod in json)
    lines = [
        "| arch | shape | step | compute_s | memory_s | collective_s | "
        "dominant | peak GB (tpu-proj) | MODEL_FLOPS | useful | "
        "to move the bound |",
        "|---|---|---|---|---|---|---|---|---|---|---|".replace(
            "|---|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---:|---:|---:|---|---:|---:|---:|---|"),
    ]
    singles = [r for r in base if r["mesh"] == "single"]
    singles.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])))
    for r in singles + sorted(variants, key=lambda r: r["arch"]):
        t = r["roofline"]
        m = r["memory"]
        proj = m.get("tpu_projected_peak_bytes", m["peak_bytes"]) / 1e9
        name = r["arch"] + (f" [{r['variant']}]" if r.get("variant") else "")
        lines.append(
            f"| {name} | {r['shape']} | {r['step']} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['collective_s']:.3g} | {t['dominant'].replace('_s','')} "
            f"| {m['peak_bytes']/1e9:.1f} ({proj:.1f}) "
            f"| {r['model_flops_global']:.2e} "
            f"| {r['useful_compute_ratio']:.2f} | {_advice(r)} |")

    # multi-pod delta table (terms only)
    lines2 = ["", "### Multi-pod (2×16×16) deltas vs single-pod", "",
              "| arch | shape | bound single→multi | collective_s "
              "single→multi | peak GB multi |", "|---|---|---|---|---:|"]
    for r in sorted([r for r in base if r["mesh"] == "multi"],
                    key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"]))):
        s = next((x for x in singles if x["arch"] == r["arch"]
                  and x["shape"] == r["shape"]), None)
        if not s:
            continue
        lines2.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {s['roofline']['bound_s']:.3g}→{r['roofline']['bound_s']:.3g} "
            f"| {s['roofline']['collective_s']:.3g}→"
            f"{r['roofline']['collective_s']:.3g} "
            f"| {r['memory']['peak_bytes']/1e9:.1f} |")

    # HWA rows
    lines3 = ["", "### HWA-variant dry-runs (replica axis = pod axis)", "",
              "| arch | step | mesh | collective traffic/step (GB/dev) | "
              "collectives | peak GB |", "|---|---|---|---:|---|---:|"]
    for r in sorted(hwa, key=lambda r: (r["arch"], r["step"], r["mesh"])):
        cts = ", ".join(f"{k}:{int(v)}" for k, v in
                        r["collectives"]["counts"].items())
        lines3.append(
            f"| {r['arch']} | {r['step']} | {r['mesh']} "
            f"| {r['collectives']['traffic_bytes_per_device']/1e9:.2f} "
            f"| {cts} | {r['memory']['peak_bytes']/1e9:.1f} |")

    return summary, "\n".join(lines + lines2 + lines3)


def main():
    summary, table = dryrun_tables()
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = re.sub(r"<!-- DRYRUN_SUMMARY -->", summary, text)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->", table, text)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
