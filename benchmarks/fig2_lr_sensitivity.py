"""Paper Fig. 2 — SWA's sensitivity to the Stage-II sampling LR, vs HWA
needing no sampling LR at all (it uses the regular cosine schedule)."""
from benchmarks.common import csv_row, run_method


def main(print_fn=print):
    accs = []
    for swa_lr in (0.3, 0.1, 0.02):
        out = run_method("swa", swa_lr=swa_lr)
        accs.append(out["best"]["test_acc"])
        print_fn(csv_row(
            f"fig2/swa_lr={swa_lr}", out["us_per_step"],
            f"best_acc={out['best']['test_acc']:.4f}"))
    hwa = run_method("hwa")
    print_fn(csv_row(
        "fig2/hwa(no sampling LR)", hwa["us_per_step"],
        f"best_acc={hwa['best']['test_acc']:.4f}"))
    spread = max(accs) - min(accs)
    print_fn(csv_row("fig2/swa_acc_spread", 0.0, f"spread={spread:.4f}"))
    return {"swa_accs": accs, "hwa": hwa["best"]["test_acc"],
            "spread": spread}


if __name__ == "__main__":
    main()
