"""Mesh-native HWA communication amortization, measured from real lowered
HLO (not dry-run artifacts): per-sync replica-axis bytes vs a per-step
gradient all-reduce baseline, on a (2,2,2) forced-host-device mesh.

The numbers quantify the paper's §I claim with the shard_map path's
structural guarantee: the inner train step's replica-axis traffic is
*identically zero* (checked), so inter-replica bytes/step = sync_bytes/H.

Runs the device-hungry part in a subprocess so the forced 8-device host
platform never leaks into the benchmark process.
"""
import json
import sys

from benchmarks.common import csv_row

_WORKER_FLAG = "--mesh-comm-worker"


def _worker():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch.hlo import collectives_crossing_axis, result_bytes
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import input_specs
    from repro.launch.steps import (make_hwa_train_step,
                                    make_mesh_hwa_sync_step,
                                    make_mesh_hwa_train_step)
    from repro.models.registry import build_model
    from repro.models.types import InputShape
    from repro.sharding.rules import make_tp_rules

    mesh = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
    rules = make_tp_rules(mesh, replica_axis="replica")
    cfg = get_smoke_config("granite-3-2b")
    lm = build_model(cfg)
    hwa_cfg = HWAConfig(n_replicas=2, window=3)
    shape = InputShape("bench", seq_len=32, global_batch=8, kind="train")
    specs, dims = input_specs(cfg, shape)

    def crossing_bytes(compiled):
        hits = collectives_crossing_axis(compiled.as_text(), mesh, "replica")
        return len(hits), result_bytes(hits)

    out = {}
    mesh_train = make_mesh_hwa_train_step(
        lm, rules, specs, dims, hwa_cfg, optimizer="sgd").lower(mesh).compile()
    out["mesh_train"] = crossing_bytes(mesh_train)
    vmap_train = make_hwa_train_step(
        lm, rules, specs, dims, hwa_cfg, optimizer="sgd").lower(mesh).compile()
    out["vmap_train"] = crossing_bytes(vmap_train)
    sync = make_mesh_hwa_sync_step(
        lm, rules, hwa_cfg).lower(mesh).compile()
    out["sync"] = crossing_bytes(sync)
    n_params = sum(
        int(jnp.prod(jnp.asarray(l.shape)))
        for l in jax.tree.leaves(lm.abstract()[0]))
    out["param_bytes"] = 4 * n_params
    # flat-vs-tree sync topologies on the pod-carved (2,2,2) mesh — the
    # same record `make bench-sync` persists into BENCH_kernels.json.
    # Recomputed here (~3 s: three small sync-bundle compiles) rather
    # than read from that file: the worker subprocesses cannot share a
    # live record, and a stale file would silently misreport. Isolated
    # so a tree-path regression cannot void the unrelated replica-byte
    # measurements above (it surfaces as a tree/ERROR row).
    try:
        from benchmarks.sync_tree import tree_sync_record
        out["tree"] = tree_sync_record()
    except Exception as e:  # noqa: BLE001 — report and keep the rest
        out["tree"] = {"error": f"{type(e).__name__}: {e}"}
    print(json.dumps(out))


def main(print_fn=print):
    from benchmarks.common import run_forced_device_worker
    rec = run_forced_device_worker(__file__, _WORKER_FLAG,
                                   error_row="mesh_comm/ERROR",
                                   print_fn=print_fn)
    if not rec:
        return {}
    mesh_n, mesh_b = rec["mesh_train"]
    vmap_n, vmap_b = rec["vmap_train"]
    sync_n, sync_b = rec["sync"]
    print_fn(csv_row("mesh_comm/train_replica_bytes/mesh_native", 0.0,
                     f"collectives={mesh_n};bytes={mesh_b}"))
    print_fn(csv_row("mesh_comm/train_replica_bytes/vmap_path", 0.0,
                     f"collectives={vmap_n};bytes={vmap_b}"))
    print_fn(csv_row("mesh_comm/sync_replica_bytes", 0.0,
                     f"collectives={sync_n};bytes={sync_b};"
                     f"param_bytes={rec['param_bytes']}"))
    # amortization: inter-replica bytes per *step* when syncing every H
    for H in (1, 64, 391, 1024):
        per_step = mesh_b + sync_b / H
        print_fn(csv_row(f"mesh_comm/bytes_per_step/H={H}", 0.0,
                         f"mesh_native={per_step:.3e};"
                         f"per_step_allreduce={sync_b:.3e}"))
    # flat vs two-level tree: modeled ICI bytes per cycle of H₂ syncs on
    # the pod-carved (2,2,2) mesh (cross-pod traffic is the tree's win)
    tree = rec.get("tree", {})
    if "error" in tree:
        print_fn(csv_row("mesh_comm/sync_tree_cycle/ERROR", 0.0,
                         tree["error"].replace(",", ";")[:160]))
    for h2, c in tree.get("per_cycle", {}).items():
        print_fn(csv_row(
            f"mesh_comm/sync_tree_cycle/{h2}", 0.0,
            f"flat_ici={c['flat_ici_bytes']:.3e};"
            f"tree_ici={c['tree_ici_bytes']:.3e};"
            f"flat_pod={c['flat_pod_bytes']:.3e};"
            f"tree_pod={c['tree_pod_bytes']:.3e}"))
    return rec


if __name__ == "__main__":
    if _WORKER_FLAG in sys.argv:
        _worker()
    else:
        main()
