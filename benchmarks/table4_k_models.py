"""Paper Table IV — number of parallel models K ∈ {2, 3, 4}.

Claim: gains are stable in K; K=2 suffices under a limited budget.
"""
from benchmarks.common import csv_row, run_method


def main(print_fn=print):
    rows = {}
    for k in (2, 3, 4):
        out = run_method("hwa", k=k)
        rows[k] = out
        print_fn(csv_row(
            f"table4/K={k}", out["us_per_step"],
            f"best_acc={out['best']['test_acc']:.4f};"
            f"best_loss={out['best']['test_loss']:.4f}"))
    return rows


if __name__ == "__main__":
    main()
