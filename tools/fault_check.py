#!/usr/bin/env python
"""fault-check: deterministic fault-injection harness over the
resilience stack — NaN-poisoned replicas, kill-mid-save preemptions,
bit-flipped checkpoints, transient IO errors — each leg an end-to-end
scenario with a hard pass/fail verdict.

Thin launcher: the mesh legs need 8 host devices, and XLA_FLAGS must be
set BEFORE jax is first imported, so this wrapper does exactly that and
then delegates to ``repro.resilience.check`` (the importable core).

    python tools/fault_check.py [--smoke] [--json PATH] [--only SUBSTR]
    make fault-check         # full set, report to fault_report.json

Exit status: 0 iff every leg passes (``REPRO_FAULT_SMOKE=1`` selects
the PR-lane subset, as in CI).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.resilience.check import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
