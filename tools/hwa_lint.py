#!/usr/bin/env python
"""hwa-lint: declarative SPMD contract checker over the compiled bundle
matrix — collectives, Pallas-launch budgets, donation/aliasing, dtype
discipline, manual-subgroup hazards.

Thin launcher: the test meshes need 8 host devices, and XLA_FLAGS must
be set BEFORE jax is first imported, so this wrapper does exactly that
and then delegates to ``repro.analysis.lint`` (the importable core).

    python tools/hwa_lint.py [--smoke] [--json PATH] [--only SUBSTR]
    make hwa-lint            # full matrix, report to lint_report.json

Exit status: 0 iff every bundle config satisfies its contract
(``REPRO_LINT_SMOKE=1`` selects the PR-lane subset, as in CI).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
