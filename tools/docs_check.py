#!/usr/bin/env python
"""Docs sanity: quickstart commands dry-run + intra-repo link check.

Two failure classes this guards against (wired into ``make test`` via the
``docs-check`` target, and into the pytest suite via tests/test_docs.py):

1. README quickstart commands referencing Make targets that no longer
   exist — every ``make <target>`` found in fenced code blocks of
   README.md is executed with ``make -n`` (dry-run: recipes are printed,
   never run), which fails on unknown targets or Makefile syntax errors.
2. Broken intra-repo markdown links — every ``[text](path)`` whose
   target is not an external URL or anchor must resolve to an existing
   file/directory relative to the linking document.

Exit code 0 iff everything passes; offending items are printed.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```(?:bash|sh|shell)?\n(.*?)```", re.S)
_MAKE_RE = re.compile(r"^\s*make\s+([A-Za-z0-9_.-]+)\s*(?:#.*)?$", re.M)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files() -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".pytest_cache")]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def check_links() -> list[str]:
    errors = []
    for path in md_files():
        text = open(path, encoding="utf-8").read()
        base = os.path.dirname(path)
        for target in _LINK_RE.findall(text):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_quickstart() -> list[str]:
    errors = []
    readme = os.path.join(ROOT, "README.md")
    if not os.path.exists(readme):
        return [f"missing {readme}"]
    text = open(readme, encoding="utf-8").read()
    targets = []
    for block in _FENCE_RE.findall(text):
        targets.extend(_MAKE_RE.findall(block))
    if not targets:
        return ["README.md quickstart names no `make` targets"]
    for t in dict.fromkeys(targets):
        proc = subprocess.run(["make", "-n", t], cwd=ROOT,
                              capture_output=True, text=True, timeout=60)
        if proc.returncode != 0:
            errors.append(f"`make -n {t}` failed: "
                          f"{(proc.stderr or proc.stdout).strip()[:160]}")
    return errors


def main() -> int:
    errors = check_links() + check_quickstart()
    for e in errors:
        print(f"DOCS-CHECK FAIL: {e}")
    if not errors:
        print(f"docs-check OK ({len(md_files())} markdown files, "
              "quickstart targets dry-run clean)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
