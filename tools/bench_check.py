#!/usr/bin/env python
"""Bench regression guard: BENCH_kernels.json vs committed thresholds.

BENCH_kernels.json is the cross-PR perf trajectory (written by ``make
bench-kernels`` / ``make bench-sync``). Its WALL TIMES are machine- and
load-dependent, so this guard deliberately ignores them; what it pins
are the STRUCTURAL claims the docs and ROADMAP make — kernel-launch
counts, collective counts, assembly bytes, padding waste, cross-pod
traffic ratios — which must hold on any machine, smoke lane included.

``benchmarks/thresholds.json`` holds two sections:

- ``required``: dotted key paths that must exist and be numbers
  (schema stability — a renamed metric fails loudly instead of silently
  vanishing from the trajectory);
- ``bounds``: ``{path: {"min": x?, "max": y?}}`` numeric guards;
- ``ulp_budgets``: ``{token: max_rel_ulp}`` bounded-ULP parity budgets
  for the compressed WA precisions — the one place those budgets live
  (tests/mesh_hwa_check.py reads the same numbers). Each budget guards
  the ``sync/comms.<token>.wa_rel_ulp_err`` bench metric when present.

Paths are dot-joined; a literal key containing dots (``sync/tree``)
wins over path splitting. Exit 0 iff every check passes; offending
entries are printed. Run via ``make bench-check`` (the CI bench-smoke
job runs it against a fresh ``make bench-kernels``).
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH = os.path.join(ROOT, "BENCH_kernels.json")
THRESHOLDS = os.path.join(ROOT, "benchmarks", "thresholds.json")


def lookup(data, path: str):
    """Resolve a dotted path; literal keys with dots (e.g. 'sync/tree'
    block names) are matched greedily before splitting."""
    node = data
    rest = path
    while rest:
        if not isinstance(node, dict):
            raise KeyError(path)
        if rest in node:
            return node[rest]
        # longest prefix of `rest` that is a literal key
        best = None
        for key in node:
            pref = key + "."
            if rest.startswith(pref) and \
                    (best is None or len(key) > len(best)):
                best = key
        if best is None:
            raise KeyError(path)
        node, rest = node[best], rest[len(best) + 1:]
    return node


#: the sections thresholds.json may contain — anything else is a typo
#: that would otherwise silently un-guard its checks
KNOWN_SECTIONS = ("required", "bounds", "ulp_budgets")


def block_of(path: str, data) -> str:
    """The top-level BENCH block a threshold path guards (literal keys
    with dots win, mirroring :func:`lookup`)."""
    if path in data or "." not in path:
        return path
    best = None
    for key in data:
        if path.startswith(key + ".") and \
                (best is None or len(key) > len(best)):
            best = key
    return best if best is not None else path.split(".", 1)[0]


def run(bench_path: str = BENCH, thresholds_path: str = THRESHOLDS,
        log=print) -> int:
    """Check one bench file against one thresholds file; returns the
    exit status (0 = every check holds). Paths are parameters so the
    regression tests can feed synthetic pairs."""
    errors = []
    warnings = []
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        log(f"FAIL {os.path.basename(bench_path)} unreadable: {e}")
        return 1
    with open(thresholds_path) as f:
        th = json.load(f)

    # a misspelled section name would silently skip every check in it
    # (underscore-prefixed keys are comments by JSON convention)
    for section in th:
        if section not in KNOWN_SECTIONS and not section.startswith("_"):
            errors.append(
                f"unknown thresholds section {section!r} (known: "
                f"{', '.join(KNOWN_SECTIONS)}) — its checks would be "
                "silently ignored")

    for path in th.get("required", []):
        try:
            v = lookup(data, path)
        except KeyError:
            errors.append(f"missing required metric: {path}")
            continue
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            errors.append(f"required metric {path} is not a number: {v!r}")

    for path, bound in th.get("bounds", {}).items():
        try:
            v = lookup(data, path)
        except KeyError:
            errors.append(f"missing bounded metric: {path}")
            continue
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            errors.append(f"bounded metric {path} is not a number: {v!r}")
            continue
        if "min" in bound and v < bound["min"]:
            errors.append(f"{path} = {v} < min {bound['min']}")
        if "max" in bound and v > bound["max"]:
            errors.append(f"{path} = {v} > max {bound['max']}")

    for tok, budget in th.get("ulp_budgets", {}).items():
        if not isinstance(budget, (int, float)) or isinstance(budget, bool):
            errors.append(f"ulp_budgets[{tok!r}] is not a number: "
                          f"{budget!r}")
            continue
        try:
            v = lookup(data, f"sync/comms.{tok}.wa_rel_ulp_err")
        except KeyError:
            continue          # bench-comms not run yet — nothing to guard
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and v > budget:
            errors.append(f"sync/comms.{tok}.wa_rel_ulp_err = {v} exceeds "
                          f"its ULP budget {budget}")

    # coverage: a RENAMED bench block leaves its thresholds dangling
    # (caught above) but ALSO leaves the new block unguarded — warn so
    # the rename updates thresholds.json instead of shedding the guard
    guarded = {block_of(p, data) for p in th.get("required", [])}
    guarded |= {block_of(p, data) for p in th.get("bounds", {})}
    for block in data:
        if block not in guarded:
            warnings.append(f"bench block {block!r} has no threshold "
                            "guarding it")

    for w in warnings:
        log(f"  warn: {w}")
    if errors:
        log(f"FAIL bench-check ({len(errors)} problem(s)):")
        for e in errors:
            log(f"  - {e}")
        return 1
    n = len(th.get("required", [])) + len(th.get("bounds", {})) \
        + len(th.get("ulp_budgets", {}))
    log(f"OK bench-check: {n} structural thresholds hold, "
        f"{len(warnings)} unguarded block(s)")
    return 0


def main() -> int:
    return run()


if __name__ == "__main__":
    sys.exit(main())
